//! Parser for XLA HLO text (the subset jax emits).
//!
//! HLO text looks like:
//!
//! ```text
//! HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0}, ...)->...}
//!
//! ENTRY %main.42 (Arg_0.1: f32[2,2], Arg_1.2: f32[2,2]) -> (f32[2,2]) {
//!   %Arg_0.1 = f32[2,2]{1,0} parameter(0)
//!   %dot.3 = f32[2,2]{1,0} dot(%Arg_0.1, %Arg_1.2),
//!       lhs_contracting_dims={1}, rhs_contracting_dims={0}
//!   ROOT %tuple.4 = (f32[2,2]{1,0}) tuple(%dot.3)
//! }
//! ```
//!
//! We extract instructions (name, opcode, shape, operands, attributes) for
//! every computation in the module. This is a *structural* parser — it
//! does not attempt to validate semantics; PJRT does that on compile.

use anyhow::{anyhow, bail, Context, Result};

/// A parsed array shape, e.g. `f32[8,17,192]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HloShape {
    pub dtype: String,
    pub dims: Vec<usize>,
    /// Tuple shapes carry elements instead.
    pub tuple: Vec<HloShape>,
}

impl HloShape {
    pub fn is_tuple(&self) -> bool {
        !self.tuple.is_empty() || self.dtype == "tuple"
    }

    pub fn elems(&self) -> usize {
        if self.is_tuple() {
            return self.tuple.iter().map(|s| s.elems()).sum();
        }
        self.dims.iter().product()
    }

    /// Bytes for this shape (sums tuple elements).
    pub fn bytes(&self) -> usize {
        if self.is_tuple() {
            return self.tuple.iter().map(|s| s.bytes()).sum();
        }
        self.elems() * dtype_bytes(&self.dtype)
    }
}

/// Element size for HLO dtype strings; `None` for dtypes this parser
/// does not know.
pub fn try_dtype_bytes(dtype: &str) -> Option<usize> {
    Some(match dtype {
        "pred" | "s8" | "u8" => 1,
        "s16" | "u16" | "f16" | "bf16" => 2,
        "s32" | "u32" | "f32" => 4,
        "s64" | "u64" | "f64" | "c64" => 8,
        "c128" => 16,
        _ => return None,
    })
}

/// Element size for HLO dtype strings. Unknown dtypes fall back to 4
/// bytes but log a loud warning — a silent guess here would quietly
/// mis-size every downstream traffic/memory estimate (the quantity the
/// paper's whole evaluation hinges on). Prefer [`try_dtype_bytes`] when
/// an unknown dtype should be an error.
pub fn dtype_bytes(dtype: &str) -> usize {
    try_dtype_bytes(dtype).unwrap_or_else(|| {
        crate::log_warn!(
            "unknown HLO dtype {dtype:?}: assuming 4 bytes — cost analysis \
             and traffic estimates involving this dtype are unreliable"
        );
        4
    })
}

/// One HLO instruction.
#[derive(Debug, Clone)]
pub struct HloInstruction {
    pub name: String,
    pub shape: HloShape,
    pub opcode: String,
    pub operands: Vec<String>,
    /// Raw attribute text after the operand list (e.g. contracting dims).
    pub attrs: String,
    pub is_root: bool,
}

/// One computation (ENTRY or subcomputation, e.g. fused/reduce bodies).
#[derive(Debug, Clone)]
pub struct HloComputation {
    pub name: String,
    pub instructions: Vec<HloInstruction>,
    pub is_entry: bool,
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub computations: Vec<HloComputation>,
}

impl HloModule {
    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().peekable();
        let header = lines
            .next()
            .ok_or_else(|| anyhow!("empty HLO text"))?;
        if !header.starts_with("HloModule") {
            bail!("not an HLO module (header: {header:?})");
        }
        let name = header
            .split_whitespace()
            .nth(1)
            .unwrap_or("unnamed")
            .trim_end_matches(',')
            .to_string();

        let mut computations = Vec::new();
        let mut current: Option<HloComputation> = None;
        let mut pending = String::new();

        for raw in lines {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            // Computation start — jax emits several header styles:
            //   "ENTRY %main.7 (Arg_0.1: f32[2,2]) -> (f32[2,2]) {"
            //   "%fused (p0: f32[2]) -> f32[2] {"
            //   "region_0.5 {"            (while bodies, reducers)
            //   "_where.3 {"
            // i.e. any top-level line ending in '{' begins a computation.
            if line.ends_with('{') && current.is_none() {
                let is_entry = line.starts_with("ENTRY");
                let name = line
                    .trim_start_matches("ENTRY")
                    .trim()
                    .split(['(', ' '])
                    .next()
                    .unwrap_or("")
                    .trim_start_matches('%')
                    .to_string();
                current = Some(HloComputation {
                    name,
                    instructions: Vec::new(),
                    is_entry,
                });
                continue;
            }
            if line == "}" {
                if let Some(c) = current.take() {
                    computations.push(c);
                }
                pending.clear();
                continue;
            }
            if let Some(c) = current.as_mut() {
                // Instructions may wrap across lines; join until balanced.
                if !pending.is_empty() {
                    pending.push(' ');
                }
                pending.push_str(line);
                if !line_complete(&pending) {
                    continue;
                }
                if let Some(inst) = parse_instruction(&pending)? {
                    c.instructions.push(inst);
                }
                pending.clear();
            }
        }
        if computations.is_empty() {
            bail!("no computations found");
        }
        Ok(Self { name, computations })
    }

    pub fn entry(&self) -> Result<&HloComputation> {
        self.computations
            .iter()
            .find(|c| c.is_entry)
            .or_else(|| self.computations.last())
            .ok_or_else(|| anyhow!("no entry computation"))
    }

    /// Entry parameters in positional order: (name, shape).
    pub fn parameters(&self) -> Result<Vec<(String, HloShape)>> {
        let entry = self.entry()?;
        let mut params: Vec<(usize, String, HloShape)> = entry
            .instructions
            .iter()
            .filter(|i| i.opcode == "parameter")
            .map(|i| {
                let pos = i
                    .attrs
                    .trim_start_matches('(')
                    .split(')')
                    .next()
                    .and_then(|s| s.trim().parse::<usize>().ok())
                    .unwrap_or(usize::MAX);
                (pos, i.name.clone(), i.shape.clone())
            })
            .collect();
        params.sort_by_key(|(pos, _, _)| *pos);
        Ok(params.into_iter().map(|(_, n, s)| (n, s)).collect())
    }

    /// Shape of the entry root.
    pub fn result_shape(&self) -> Result<HloShape> {
        let entry = self.entry()?;
        entry
            .instructions
            .iter()
            .find(|i| i.is_root)
            .or_else(|| entry.instructions.last())
            .map(|i| i.shape.clone())
            .ok_or_else(|| anyhow!("entry has no instructions"))
    }
}

/// True when parens/braces/brackets are balanced (instruction complete).
fn line_complete(s: &str) -> bool {
    let mut depth = 0i64;
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => in_str = !in_str,
            '(' | '{' | '[' if !in_str => depth += 1,
            ')' | '}' | ']' if !in_str => depth -= 1,
            _ => {}
        }
    }
    depth <= 0 && !in_str
}

/// Parse `%name = shape opcode(operands), attrs` (or `ROOT %name = ...`).
fn parse_instruction(line: &str) -> Result<Option<HloInstruction>> {
    let mut rest = line.trim();
    let is_root = rest.starts_with("ROOT ");
    if is_root {
        rest = &rest[5..];
    }
    if !rest.starts_with('%') && !rest.chars().next().is_some_and(|c| c.is_ascii_alphabetic()) {
        return Ok(None);
    }
    let (lhs, rhs) = rest
        .split_once('=')
        .ok_or_else(|| anyhow!("instruction without '=': {line:?}"))?;
    let name = lhs.trim().trim_start_matches('%').to_string();
    let rhs = rhs.trim();
    // rhs = "<shape> <opcode>(<operands>)<attrs>"
    let (shape_str, after_shape) = split_shape(rhs)?;
    let shape = parse_shape(shape_str)?;
    let after_shape = after_shape.trim();
    let paren = after_shape
        .find('(')
        .ok_or_else(|| anyhow!("no opcode call in {line:?}"))?;
    let opcode = after_shape[..paren].trim().to_string();
    let close = matching_paren(after_shape, paren)
        .ok_or_else(|| anyhow!("unbalanced parens in {line:?}"))?;
    let operands_str = &after_shape[paren + 1..close];
    let attrs = after_shape[close + 1..]
        .trim_start_matches(',')
        .trim()
        .to_string();
    let operands = if opcode == "parameter" || opcode == "constant" {
        Vec::new()
    } else {
        split_top_level(operands_str)
            .into_iter()
            .map(|s| {
                s.trim()
                    .split_whitespace()
                    .last()
                    .unwrap_or("")
                    .trim_start_matches('%')
                    .to_string()
            })
            .filter(|s| !s.is_empty())
            .collect()
    };
    // The parens carry the parameter index for `parameter` and the
    // literal payload for `constant`; keep both in attrs (the runtime
    // interpreter materializes constants from it).
    let attrs = if opcode == "parameter" || opcode == "constant" {
        format!("({operands_str}){attrs}")
    } else {
        attrs
    };
    Ok(Some(HloInstruction { name, shape, opcode, operands, attrs, is_root }))
}

/// Split the leading shape token (handles tuples with nested commas and
/// layout annotations `{1,0}`).
fn split_shape(s: &str) -> Result<(&str, &str)> {
    if s.starts_with('(') {
        let close = matching_paren(s, 0)
            .ok_or_else(|| anyhow!("unbalanced tuple shape in {s:?}"))?;
        return Ok((&s[..close + 1], &s[close + 1..]));
    }
    // array shape ends at the first space that is not inside {} or []
    let mut depth = 0;
    for (i, c) in s.char_indices() {
        match c {
            '[' | '{' => depth += 1,
            ']' | '}' => depth -= 1,
            ' ' if depth == 0 => return Ok((&s[..i], &s[i..])),
            _ => {}
        }
    }
    Ok((s, ""))
}

fn matching_paren(s: &str, open: usize) -> Option<usize> {
    let b = s.as_bytes();
    let mut depth = 0;
    for i in open..b.len() {
        match b[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Split on top-level commas (ignoring nested (), {}, []).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth -= 1,
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        out.push(&s[start..]);
    }
    out
}

/// Parse `f32[8,17]{1,0}` or `(f32[2]{0}, u8[3]{0})` or `f32[]`.
pub fn parse_shape(s: &str) -> Result<HloShape> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        let inner = inner.strip_suffix(')').unwrap_or(inner);
        let tuple = split_top_level(inner)
            .into_iter()
            .map(|p| parse_shape(p))
            .collect::<Result<Vec<_>>>()?;
        return Ok(HloShape { dtype: "tuple".into(), dims: vec![], tuple });
    }
    let bracket = s.find('[');
    let (dtype, rest) = match bracket {
        Some(b) => (&s[..b], &s[b..]),
        None => (s, ""),
    };
    let dims = if rest.is_empty() {
        vec![]
    } else {
        let close = rest
            .find(']')
            .ok_or_else(|| anyhow!("unterminated dims in shape {s:?}"))?;
        let body = &rest[1..close];
        if body.trim().is_empty() {
            vec![]
        } else {
            body.split(',')
                .map(|d| {
                    d.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad dim {d:?} in shape {s:?}"))
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    Ok(HloShape { dtype: dtype.to_string(), dims, tuple: vec![] })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY %main.7 (Arg_0.1: f32[2,2], Arg_1.2: f32[2,2]) -> (f32[2,2]) {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %Arg_1.2 = f32[2,2]{1,0} parameter(1)
  %dot.3 = f32[2,2]{1,0} dot(%Arg_0.1, %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %constant.4 = f32[] constant(2)
  %broadcast.5 = f32[2,2]{1,0} broadcast(%constant.4), dimensions={}
  ROOT %add.6 = f32[2,2]{1,0} add(%dot.3, %broadcast.5)
}
"#;

    #[test]
    fn parses_sample() {
        let m = HloModule::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "jit_fn");
        let e = m.entry().unwrap();
        assert!(e.is_entry);
        assert_eq!(e.instructions.len(), 6);
        let dot = &e.instructions[2];
        assert_eq!(dot.opcode, "dot");
        assert_eq!(dot.operands, vec!["Arg_0.1", "Arg_1.2"]);
        assert!(dot.attrs.contains("lhs_contracting_dims={1}"));
        assert_eq!(dot.shape.dims, vec![2, 2]);
        assert!(e.instructions[5].is_root);
        // constants keep their literal payload in attrs
        let c = &e.instructions[3];
        assert_eq!(c.opcode, "constant");
        assert!(c.attrs.starts_with("(2)"), "attrs = {:?}", c.attrs);
    }

    #[test]
    fn dtype_bytes_unknown_is_not_silent() {
        assert_eq!(try_dtype_bytes("f32"), Some(4));
        assert_eq!(try_dtype_bytes("bf16"), Some(2));
        assert_eq!(try_dtype_bytes("f8e4m3"), None);
        // the lenient path still answers (with a logged warning)
        assert_eq!(dtype_bytes("f8e4m3"), 4);
    }

    #[test]
    fn parameters_ordered() {
        let m = HloModule::parse(SAMPLE).unwrap();
        let ps = m.parameters().unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].0, "Arg_0.1");
        assert_eq!(ps[0].1.dims, vec![2, 2]);
    }

    #[test]
    fn shape_parsing() {
        let s = parse_shape("f32[8,17,192]{2,1,0}").unwrap();
        assert_eq!(s.dims, vec![8, 17, 192]);
        assert_eq!(s.bytes(), 8 * 17 * 192 * 4);
        let t = parse_shape("(f32[2]{0}, u8[3]{0})").unwrap();
        assert!(t.is_tuple());
        assert_eq!(t.bytes(), 8 + 3);
        let scalar = parse_shape("f32[]").unwrap();
        assert_eq!(scalar.elems(), 1);
        let u8s = parse_shape("u8[192,576]{1,0}").unwrap();
        assert_eq!(u8s.bytes(), 192 * 576);
    }

    #[test]
    fn multiline_instruction_joined() {
        let text = "HloModule m\nENTRY %e (a: f32[2]) -> f32[2] {\n  %a = f32[2]{0} parameter(0)\n  ROOT %r = f32[2]{0} add(%a,\n      %a)\n}\n";
        let m = HloModule::parse(text).unwrap();
        let e = m.entry().unwrap();
        assert_eq!(e.instructions[1].operands, vec!["a", "a"]);
    }

    #[test]
    fn rejects_non_hlo() {
        assert!(HloModule::parse("not hlo").is_err());
        assert!(HloModule::parse("").is_err());
    }

    #[test]
    fn result_shape() {
        let m = HloModule::parse(SAMPLE).unwrap();
        assert_eq!(m.result_shape().unwrap().dims, vec![2, 2]);
    }
}
