//! HLO-text parsing and cost analysis.
//!
//! The AOT artifacts are HLO *text* modules; this module parses enough of
//! that text to (a) validate artifacts before PJRT compilation, (b) count
//! FLOPs and bytes per op category for the Fig. 2/3 breakdowns, and
//! (c) feed the platform simulator with per-inference traffic estimates.

pub mod cost;
pub mod parser;

pub use cost::{CostAnalysis, OpCategory};
pub use parser::{HloInstruction, HloModule, HloShape};
