//! Kernel microbench: the interpreter's matmul paths on a 256x256x256
//! problem (no artifacts needed).
//!
//! * `naive`     — the pre-PR-2 index-walk `dot` (reference semantics);
//! * `blocked`   — the cache-blocked, register-tiled, threaded GEMM the
//!                 interpreter now dispatches `dot` to;
//! * `clustered` — the LUT-accumulation kernel on 64-cluster weights
//!                 (6-bit packed indices + codebook, never dequantized).
//!
//! Besides wall time, reports the weight bytes each kernel streams per
//! matmul — the quantity the paper's >4x memory-traffic claim is about.
//! Acceptance targets (ISSUE 2): blocked >= 5x naive; clustered weight
//! stream >= 4x smaller than dense.

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::runtime::interp::clustered::{lut_matmul_packed, prepare};
use clusterformer::runtime::interp::gemm::{dot_general, dot_general_naive, DotSpec};
use clusterformer::runtime::ThreadBudget;
use clusterformer::tensor::Tensor;
use clusterformer::util::rng::Pcg32;

const M: usize = 256;
const K: usize = 256;
const N: usize = 256;
const CLUSTERS: usize = 64;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::new(210616006);
    let x: Vec<f32> = (0..M * K).map(|_| rng.normal() as f32).collect();
    let codebook: Vec<f32> = (0..CLUSTERS).map(|_| rng.normal() as f32).collect();
    let idx: Vec<u8> = (0..K * N).map(|_| rng.range(0, CLUSTERS - 1) as u8).collect();
    let w: Vec<f32> = idx.iter().map(|&i| codebook[i as usize]).collect();

    let lhs = Tensor::from_f32(vec![M, K], &x)?;
    let rhs = Tensor::from_f32(vec![K, N], &w)?;
    let spec = DotSpec {
        lhs_contracting: vec![1],
        rhs_contracting: vec![0],
        ..Default::default()
    };
    let prep = prepare(&idx, K, N, &codebook, Some(CLUSTERS))?;

    let threads = ThreadBudget::from_env().get();
    println!("# GEMM kernels — {M}x{K}x{N}, {CLUSTERS} clusters, {threads} threads\n");
    let mut runner = BenchRunner::new(BenchConfig::default());
    let naive = runner
        .bench("dot/naive-index-walk", || dot_general_naive(&lhs, &rhs, &spec).unwrap())
        .summary
        .mean;
    let blocked = runner
        .bench("dot/blocked-gemm", || dot_general(&lhs, &rhs, &spec, threads).unwrap())
        .summary
        .mean;
    let lut = runner
        .bench("dot/clustered-lut", || lut_matmul_packed(&x, M, &prep, threads).unwrap())
        .summary
        .mean;

    let dense_bytes = prep.dense_bytes();
    let lut_bytes = prep.weight_bytes();
    println!("\n| kernel | mean | speedup vs naive | weight bytes/call |");
    println!("|---|---|---|---|");
    println!("| naive index-walk | {} | 1.00x | {dense_bytes} |", fmt_time(naive));
    println!(
        "| blocked GEMM | {} | {:.2}x | {dense_bytes} |",
        fmt_time(blocked),
        naive / blocked
    );
    println!(
        "| clustered LUT ({}-bit packed) | {} | {:.2}x | {lut_bytes} |",
        prep.bits(),
        fmt_time(lut),
        naive / lut
    );
    println!(
        "\nblocked vs naive: {:.2}x (target >= 5x: {})",
        naive / blocked,
        if naive / blocked >= 5.0 { "MET" } else { "NOT met" }
    );
    println!(
        "clustered weight stream: {dense_bytes} -> {lut_bytes} bytes, {:.2}x fewer (target >= 4x: {})",
        dense_bytes as f64 / lut_bytes as f64,
        if dense_bytes as f64 / lut_bytes as f64 >= 4.0 { "MET" } else { "NOT met" }
    );

    // Numeric cross-check so a broken kernel can't silently post a win.
    let reference = dot_general_naive(&lhs, &rhs, &spec)?.as_f32()?;
    let fast = dot_general(&lhs, &rhs, &spec, threads)?.as_f32()?;
    assert_eq!(reference, fast, "blocked GEMM must match naive bit-for-bit");
    let clustered_out = lut_matmul_packed(&x, M, &prep, threads)?;
    for (a, b) in clustered_out.iter().zip(&reference) {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "clustered LUT diverged: {a} vs {b}"
        );
    }
    runner.finish("gemm kernels");
    Ok(())
}
