//! Kernel microbench: the interpreter's matmul paths on a 256x256x256
//! problem (no artifacts needed).
//!
//! * `naive`     — the pre-PR-2 index-walk `dot` (reference semantics);
//! * `blocked`   — the cache-blocked, register-tiled, threaded GEMM the
//!                 interpreter now dispatches `dot` to;
//! * `clustered` — the LUT-accumulation kernel on 64-cluster weights
//!                 (6-bit packed indices + codebook, never dequantized);
//! * `scalar vs SIMD A/B` — the blocked GEMM and the LUT kernel run
//!                 again with the dispatch level forced to `scalar` and
//!                 to the detected vector level, so the SIMD microkernel
//!                 win is measured on its own rather than inferred.
//!
//! Besides wall time, reports GFLOP/s, the weight bytes each kernel
//! streams per matmul — the quantity the paper's >4x memory-traffic
//! claim is about — and bytes touched per CPU cycle (when /proc/cpuinfo
//! exposes a clock). Emits machine-readable `BENCH_kernels.json` next to
//! the markdown/CSV report.
//!
//! Acceptance targets: blocked >= 5x naive (ISSUE 2); SIMD GEMM >= 2x
//! scalar GEMM and a measurable SIMD LUT win on AVX2 hosts (ISSUE 6).

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::runtime::interp::clustered::{lut_matmul_packed, prepare};
use clusterformer::runtime::interp::gemm::{dot_general, dot_general_naive, DotSpec};
use clusterformer::runtime::interp::{detected_kernel_isa, force_kernel_isa, KernelIsa};
use clusterformer::runtime::ThreadBudget;
use clusterformer::tensor::Tensor;
use clusterformer::util::rng::Pcg32;

const M: usize = 256;
const K: usize = 256;
const N: usize = 256;
const CLUSTERS: usize = 64;

/// Nominal core clock in Hz from `/proc/cpuinfo` (`cpu MHz`), when the
/// platform exposes one — bytes-per-cycle is reported only then.
fn cpu_hz() -> Option<f64> {
    let info = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    for line in info.lines() {
        if let Some(rest) = line.strip_prefix("cpu MHz") {
            let mhz: f64 = rest.trim_start().strip_prefix(':')?.trim().parse().ok()?;
            return Some(mhz * 1e6);
        }
    }
    None
}

fn json_f64(x: Option<f64>) -> String {
    match x {
        Some(v) if v.is_finite() => format!("{v:.6}"),
        _ => "null".to_string(),
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg32::new(210616006);
    let x: Vec<f32> = (0..M * K).map(|_| rng.normal() as f32).collect();
    let codebook: Vec<f32> = (0..CLUSTERS).map(|_| rng.normal() as f32).collect();
    let idx: Vec<u8> = (0..K * N).map(|_| rng.range(0, CLUSTERS - 1) as u8).collect();
    let w: Vec<f32> = idx.iter().map(|&i| codebook[i as usize]).collect();

    let lhs = Tensor::from_f32(vec![M, K], &x)?;
    let rhs = Tensor::from_f32(vec![K, N], &w)?;
    let spec = DotSpec {
        lhs_contracting: vec![1],
        rhs_contracting: vec![0],
        ..Default::default()
    };
    let prep = prepare(&idx, K, N, &codebook, Some(CLUSTERS))?;

    let threads = ThreadBudget::from_env().get();
    let detected = detected_kernel_isa();
    println!(
        "# GEMM kernels — {M}x{K}x{N}, {CLUSTERS} clusters, {threads} threads, \
         detected ISA {}\n",
        detected.name()
    );
    let mut runner = BenchRunner::new(BenchConfig::default());
    let naive = runner
        .bench("dot/naive-index-walk", || dot_general_naive(&lhs, &rhs, &spec).unwrap())
        .summary
        .mean;
    let blocked = runner
        .bench("dot/blocked-gemm", || dot_general(&lhs, &rhs, &spec, threads).unwrap())
        .summary
        .mean;
    let lut = runner
        .bench("dot/clustered-lut", || lut_matmul_packed(&x, M, &prep, threads).unwrap())
        .summary
        .mean;

    // ---- scalar vs SIMD A/B, same problem, dispatch level forced ----
    let mut levels = vec![KernelIsa::Scalar];
    if detected != KernelIsa::Scalar {
        levels.push(detected);
    }
    let mut gemm_by_isa: Vec<(KernelIsa, f64)> = Vec::new();
    let mut lut_by_isa: Vec<(KernelIsa, f64)> = Vec::new();
    for &isa in &levels {
        force_kernel_isa(Some(isa));
        let g = runner
            .bench(&format!("dot/blocked-gemm@{}", isa.name()), || {
                dot_general(&lhs, &rhs, &spec, threads).unwrap()
            })
            .summary
            .mean;
        gemm_by_isa.push((isa, g));
        let l = runner
            .bench(&format!("dot/clustered-lut@{}", isa.name()), || {
                lut_matmul_packed(&x, M, &prep, threads).unwrap()
            })
            .summary
            .mean;
        lut_by_isa.push((isa, l));
    }
    force_kernel_isa(None);

    let flops = (2 * M * K * N) as f64;
    // Minimum streamed bytes per GEMM call: both operands + the output,
    // f32 each (ignores favorable cache reuse, so it is a lower bound).
    let gemm_bytes = ((M * K + K * N + M * N) * 4) as f64;
    let lut_bytes_touched = ((M * K + M * N) * 4) as f64 + prep.weight_bytes() as f64;
    let hz = cpu_hz();

    let dense_bytes = prep.dense_bytes();
    let lut_bytes = prep.weight_bytes();
    println!("\n| kernel | mean | speedup vs naive | GFLOP/s | bytes/cycle | weight bytes/call |");
    println!("|---|---|---|---|---|---|");
    let bpc = |mean: f64, bytes: f64| {
        hz.map(|hz| format!("{:.3}", bytes / (mean * hz))).unwrap_or_else(|| "-".into())
    };
    println!(
        "| naive index-walk | {} | 1.00x | {:.2} | {} | {dense_bytes} |",
        fmt_time(naive),
        flops / naive / 1e9,
        bpc(naive, gemm_bytes)
    );
    println!(
        "| blocked GEMM | {} | {:.2}x | {:.2} | {} | {dense_bytes} |",
        fmt_time(blocked),
        naive / blocked,
        flops / blocked / 1e9,
        bpc(blocked, gemm_bytes)
    );
    println!(
        "| clustered LUT ({}-bit packed) | {} | {:.2}x | {:.2} | {} | {lut_bytes} |",
        prep.bits(),
        fmt_time(lut),
        naive / lut,
        flops / lut / 1e9,
        bpc(lut, lut_bytes_touched)
    );
    for &(isa, g) in &gemm_by_isa {
        println!(
            "| blocked GEMM @{} | {} | {:.2}x | {:.2} | {} | {dense_bytes} |",
            isa.name(),
            fmt_time(g),
            naive / g,
            flops / g / 1e9,
            bpc(g, gemm_bytes)
        );
    }
    for &(isa, l) in &lut_by_isa {
        println!(
            "| clustered LUT @{} | {} | {:.2}x | {:.2} | {} | {lut_bytes} |",
            isa.name(),
            fmt_time(l),
            naive / l,
            flops / l / 1e9,
            bpc(l, lut_bytes_touched)
        );
    }
    println!(
        "\nblocked vs naive: {:.2}x (target >= 5x: {})",
        naive / blocked,
        if naive / blocked >= 5.0 { "MET" } else { "NOT met" }
    );
    println!(
        "clustered weight stream: {dense_bytes} -> {lut_bytes} bytes, {:.2}x fewer (target >= 4x: {})",
        dense_bytes as f64 / lut_bytes as f64,
        if dense_bytes as f64 / lut_bytes as f64 >= 4.0 { "MET" } else { "NOT met" }
    );
    let gemm_scalar = gemm_by_isa[0].1;
    let lut_scalar = lut_by_isa[0].1;
    if let (Some(&(isa, gemm_simd)), Some(&(_, lut_simd))) =
        (gemm_by_isa.get(1), lut_by_isa.get(1))
    {
        println!(
            "SIMD GEMM ({}) vs scalar: {:.2}x (target >= 2x: {})",
            isa.name(),
            gemm_scalar / gemm_simd,
            if gemm_scalar / gemm_simd >= 2.0 { "MET" } else { "NOT met" }
        );
        println!(
            "SIMD LUT ({}) vs scalar: {:.2}x (target > 1x: {})",
            isa.name(),
            lut_scalar / lut_simd,
            if lut_scalar / lut_simd > 1.0 { "MET" } else { "NOT met" }
        );
    } else {
        println!("no vector ISA detected: SIMD A/B skipped (scalar only)");
    }

    // ---- machine-readable record next to the md/csv report ----
    let mut results_json = String::new();
    let mut push_result = |name: &str, isa: &str, mean: f64, bytes: f64| {
        if !results_json.is_empty() {
            results_json.push_str(",\n    ");
        }
        results_json.push_str(&format!(
            "{{\"name\": \"{name}\", \"isa\": \"{isa}\", \"mean_s\": {mean:.9}, \
             \"gflops\": {:.3}, \"bytes_per_cycle\": {}}}",
            flops / mean / 1e9,
            json_f64(hz.map(|hz| bytes / (mean * hz)))
        ));
    };
    push_result("naive", "scalar", naive, gemm_bytes);
    push_result("blocked_gemm", "auto", blocked, gemm_bytes);
    push_result("clustered_lut", "auto", lut, lut_bytes_touched);
    for &(isa, g) in &gemm_by_isa {
        push_result("blocked_gemm", isa.name(), g, gemm_bytes);
    }
    for &(isa, l) in &lut_by_isa {
        push_result("clustered_lut", isa.name(), l, lut_bytes_touched);
    }
    let simd_gemm_speedup = gemm_by_isa.get(1).map(|&(_, g)| gemm_scalar / g);
    let simd_lut_speedup = lut_by_isa.get(1).map(|&(_, l)| lut_scalar / l);
    let json = format!(
        "{{\n  \"bench\": \"kernels\",\n  \"shape\": [{M}, {K}, {N}],\n  \
         \"clusters\": {CLUSTERS},\n  \"threads\": {threads},\n  \
         \"detected_isa\": \"{}\",\n  \"cpu_mhz\": {},\n  \"results\": [\n    {results_json}\n  ],\n  \
         \"speedups\": {{\n    \"blocked_vs_naive\": {:.3},\n    \
         \"simd_gemm_vs_scalar\": {},\n    \"simd_lut_vs_scalar\": {}\n  }}\n}}\n",
        detected.name(),
        json_f64(hz.map(|h| h / 1e6)),
        naive / blocked,
        json_f64(simd_gemm_speedup),
        json_f64(simd_lut_speedup),
    );
    let path = std::path::Path::new("BENCH_kernels.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    // Numeric cross-check so a broken kernel can't silently post a win.
    let reference = dot_general_naive(&lhs, &rhs, &spec)?.as_f32()?;
    let fast = dot_general(&lhs, &rhs, &spec, threads)?.as_f32()?;
    assert_eq!(reference, fast, "blocked GEMM must match naive bit-for-bit");
    let clustered_out = lut_matmul_packed(&x, M, &prep, threads)?;
    for (a, b) in clustered_out.iter().zip(&reference) {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "clustered LUT diverged: {a} vs {b}"
        );
    }
    // And the forced levels really were what ran: scalar vs SIMD must be
    // bit-identical on this problem, per the dispatch contract.
    force_kernel_isa(Some(KernelIsa::Scalar));
    let scalar_bits = dot_general(&lhs, &rhs, &spec, threads)?.as_f32()?;
    force_kernel_isa(None);
    let auto_bits = dot_general(&lhs, &rhs, &spec, threads)?.as_f32()?;
    assert_eq!(scalar_bits, auto_bits, "SIMD GEMM must match scalar bit-for-bit");
    runner.finish("gemm kernels");
    Ok(())
}
