//! Shared engine for the Fig. 7 / Fig. 8 accuracy sweeps: run every
//! (scheme, cluster-count) variant of one model through the *Rust
//! runtime* (the clustered HLO with the in-kernel indirect fetch) over
//! the validation set and emit the paper-style accuracy table.

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::eval::evaluate;
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::default_backend;

pub fn run_sweep(model: &str, fig: &str, n_images: usize) -> anyhow::Result<()> {
    let backend = default_backend()?;
    let mut registry = Registry::load("artifacts")?;
    let sweep = registry.manifest.cluster_sweep.clone();

    println!("# {fig} — {model} top-1/top-5 vs number of clusters ({n_images} images, Rust runtime)\n");
    let base = evaluate(
        backend.as_ref(),
        &mut registry,
        model,
        VariantKey::Baseline,
        n_images,
    )?;
    println!(
        "baseline: top1={:.4} top5={:.4} ({:.1} img/s)\n",
        base.top1, base.top5, base.images_per_s
    );
    println!("| scheme | clusters | top1 | Δtop1 (pt) | top5 | Δtop5 (pt) |");
    println!("|---|---|---|---|---|---|");
    let mut low_c: Vec<(String, f64)> = Vec::new();
    let mut max_loss_at_64 = 0.0f64;
    for scheme in [ClusterScheme::Entire, ClusterScheme::PerLayer] {
        for &c in &sweep {
            let key = VariantKey::Clustered { scheme, clusters: c };
            let r = evaluate(backend.as_ref(), &mut registry, model, key, n_images)?;
            println!(
                "| {} | {} | {:.4} | {:+.2} | {:.4} | {:+.2} |",
                scheme.name(),
                c,
                r.top1,
                (r.top1 - base.top1) * 100.0,
                r.top5,
                (r.top5 - base.top5) * 100.0,
            );
            if c == sweep[0] {
                low_c.push((scheme.name().to_string(), r.top1));
            }
            if c == 64 {
                max_loss_at_64 = max_loss_at_64.max(base.top1 - r.top1);
            }
        }
    }
    if let [(_, entire), (_, perlayer)] = &low_c[..] {
        let per_layer_beats_entire_low_c = perlayer >= entire;
        println!(
            "\npaper check: per-layer ≥ entire at the lowest cluster count \
             ({perlayer:.4} vs {entire:.4}): {}",
            if per_layer_beats_entire_low_c { "REPRODUCED" } else { "NOT reproduced" }
        );
    }
    println!(
        "paper check: ≤0.3pt top-1 loss at 64 clusters (measured {:.2}pt): {}",
        max_loss_at_64 * 100.0,
        if max_loss_at_64 <= 0.005 { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}

/// Image count for the sweep (override with SWEEP_N).
pub fn sweep_n() -> usize {
    std::env::var("SWEEP_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}
