//! Ablation A1: dynamic-batcher policy (size-only vs deadline vs
//! adaptive) under low/medium/high Poisson load, measured end-to-end on
//! the real serving stack.

use std::sync::Arc;
use std::time::{Duration, Instant};

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::{
    BatchPolicy, BatcherConfig, Server, ServerConfig,
};
use clusterformer::model::{Registry, VariantKey};
use clusterformer::tensor::Tensor;
use clusterformer::util::rng::Pcg32;
use clusterformer::util::stats::percentile_sorted;

const DURATION_S: f64 = 4.0;

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    let (images, _) = registry.val_set()?;
    println!("# A1 — batcher policy ablation (vit/perlayer_64, {DURATION_S}s per point)\n");
    println!("| policy | rate | p50 | p99 | throughput | mean batch |");
    println!("|---|---|---|---|---|---|");
    for policy in [BatchPolicy::SizeOnly, BatchPolicy::Deadline, BatchPolicy::Adaptive] {
        // One server per policy so metrics are isolated.
        let server = Server::start(ServerConfig {
            artifacts_dir: "artifacts".into(),
            backend: clusterformer::runtime::BackendKind::from_env()?,
            targets: vec![(
                "vit".to_string(),
                VariantKey::Clustered {
                    scheme: ClusterScheme::PerLayer,
                    clusters: 64,
                },
            )],
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(15),
                policy,
                queue_cap: 4096,
            },
            threads: clusterformer::runtime::ThreadBudget::from_env(),
        })?;
        let router = Arc::new(server.router.clone());
        for rate in [15.0, 60.0, 150.0] {
            let mut rng = Pcg32::new(99);
            let mut pending = Vec::new();
            let t0 = Instant::now();
            let mut n = 0usize;
            while t0.elapsed().as_secs_f64() < DURATION_S {
                std::thread::sleep(Duration::from_secs_f64(
                    rng.exponential(rate).min(0.5),
                ));
                let row = n % images.shape()[0];
                let mut img = images.slice_rows(row, row + 1)?;
                let s = img.shape()[1..].to_vec();
                img.reshape(s)?;
                pending.push(router.submit("vit/perlayer_64", img)?.1);
                n += 1;
            }
            let mut lat: Vec<f64> = Vec::new();
            // Short timeout: under SizeOnly the final partial batch is
            // (by design) stuck until shutdown — don't wait a minute per
            // stranded request, just count it out of the throughput.
            for rx in pending {
                if let Ok(r) = rx.recv_timeout(Duration::from_secs(3)) {
                    if !r.logits.is_empty() {
                        lat.push(r.latency_s);
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            lat.sort_by(|a, b| a.total_cmp(b));
            let snap = server.snapshot();
            let mean_batch = snap
                .per_variant
                .values()
                .map(|v| v.mean_batch_size())
                .next()
                .unwrap_or(0.0);
            println!(
                "| {:?} | {:.0}/s | {:.2}ms | {:.2}ms | {:.1}/s | {:.2} |",
                policy,
                rate,
                percentile_sorted(&lat, 0.5) * 1e3,
                percentile_sorted(&lat, 0.99) * 1e3,
                lat.len() as f64 / wall,
                mean_batch,
            );
        }
        server.shutdown();
    }
    println!(
        "\nexpected shape: SizeOnly has pathological tail latency at low rate \
         (batches never fill); Adaptive matches Deadline's tail while \
         forming larger batches at high rate."
    );
    Ok(())
}

// keep Tensor import used in signature position
#[allow(unused)]
fn _t(_: &Tensor) {}
