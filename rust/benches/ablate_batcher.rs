//! Ablation A1: dynamic-batcher policy (size-only vs deadline vs
//! adaptive) under low/medium/high Poisson load, measured end-to-end on
//! the real serving stack (needs prebuilt `artifacts/`; skips visibly
//! without them).
//!
//! Ablation A2 (always runs, artifact-free): **overload behavior with
//! and without SLO degradation**. A synthetic two-variant server whose
//! primary is slowed by a deterministic injected fault (known capacity)
//! is driven at 0.5x/1x/2x/4x capacity; for each point we measure
//! goodput, shed rate, SLO attainment, and the variant mix, with
//! degradation off vs on (fallback to the cheap clustered variant).
//! Emits machine-readable `BENCH_overload.json` and asserts that
//! degradation improves SLO attainment at the top overload point.

use std::sync::Arc;
use std::time::{Duration, Instant};

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::{
    faults, BatchPolicy, BatcherConfig, ReplyStatus, ResilienceConfig, Server,
    ServerConfig, SubmitError,
};
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::testing::synthetic::SyntheticServing;
use clusterformer::util::rng::Pcg32;
use clusterformer::util::stats::percentile_sorted;

const DURATION_S: f64 = 4.0;

/// Injected per-batch execution time of the overload primary: with
/// max_batch 4 the primary's capacity is ~4/SLOW_MS req/ms.
const SLOW_MS: u64 = 10;
const OV_MAX_BATCH: usize = 4;
/// End-to-end latency a request must beat to "attain the SLO" in A2.
const ATTAIN_MS: f64 = 50.0;
/// Seconds of offered load per A2 point.
const OV_DURATION_S: f64 = 1.2;

struct OverloadPoint {
    degrade: bool,
    mult: f64,
    offered_rate: f64,
    submitted: usize,
    completed: usize,
    shed: usize,
    timed_out: usize,
    failed: usize,
    goodput: f64,
    attainment: f64,
    p50_ms: f64,
    p95_ms: f64,
    primary_served: usize,
    fallback_served: usize,
}

fn overload_point(
    synth: &SyntheticServing,
    degrade: bool,
    mult: f64,
    capacity: f64,
) -> anyhow::Result<OverloadPoint> {
    let primary = synth.baseline_target();
    let fallback = synth.clustered_target();
    let mut resilience = ResilienceConfig {
        queue_bound: 64,
        window: Duration::from_millis(100),
        hold: Duration::from_millis(50),
        ..ResilienceConfig::default()
    };
    if degrade {
        resilience.slo = Some(Duration::from_millis(20));
        resilience.fallback.insert(primary.clone(), fallback.clone());
        resilience.accuracy.insert(primary.clone(), 0.9);
        resilience.accuracy.insert(fallback.clone(), 0.8);
    }
    let server = Server::start(ServerConfig {
        artifacts_dir: synth.dir.clone(),
        targets: vec![
            (synth.model.clone(), VariantKey::Baseline),
            (synth.model.clone(), SyntheticServing::clustered_key()),
        ],
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: OV_MAX_BATCH,
            max_wait: Duration::from_millis(2),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        threads: ThreadBudget::new(2),
        resilience,
    })?;

    let offered_rate = capacity * mult;
    let router = server.router.clone();
    let mut pending = Vec::new();
    let mut shed = 0usize;
    let mut submitted = 0usize;
    let t0 = Instant::now();
    // Deficit-paced open loop: submit whatever the offered rate says
    // should have been sent by now, then sleep briefly — accurate at
    // rates well above the sleep granularity.
    loop {
        let elapsed = t0.elapsed().as_secs_f64();
        if elapsed >= OV_DURATION_S {
            break;
        }
        let due = (elapsed * offered_rate) as usize;
        while submitted < due {
            let img = SyntheticServing::image(submitted as u64 + 1);
            match router.submit(&primary, img) {
                Ok((_, rx)) => pending.push(rx),
                Err(SubmitError::Overloaded { .. }) => shed += 1,
                Err(e) => return Err(e.into()),
            }
            submitted += 1;
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    let mut lat_ms: Vec<f64> = Vec::new();
    let mut completed = 0usize;
    let mut timed_out = 0usize;
    let mut failed = 0usize;
    let mut primary_served = 0usize;
    let mut fallback_served = 0usize;
    for rx in &pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every admitted request must get a terminal reply");
        match resp.status {
            ReplyStatus::Completed => {
                completed += 1;
                lat_ms.push(resp.latency_s * 1e3);
                if resp.served_by.starts_with(primary.as_str()) {
                    primary_served += 1;
                } else {
                    fallback_served += 1;
                }
            }
            ReplyStatus::Timeout => timed_out += 1,
            ReplyStatus::Overloaded => shed += 1,
            ReplyStatus::Failed => failed += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    lat_ms.sort_by(|a, b| a.total_cmp(b));
    let attained = lat_ms.iter().filter(|&&l| l <= ATTAIN_MS).count();
    let total = submitted.max(1);
    let pctl = |q| if lat_ms.is_empty() { 0.0 } else { percentile_sorted(&lat_ms, q) };
    Ok(OverloadPoint {
        degrade,
        mult,
        offered_rate,
        submitted,
        completed,
        shed,
        timed_out,
        failed,
        goodput: completed as f64 / wall,
        attainment: attained as f64 / total as f64,
        p50_ms: pctl(0.5),
        p95_ms: pctl(0.95),
        primary_served,
        fallback_served,
    })
}

fn overload_sweep() -> anyhow::Result<()> {
    println!(
        "# A2 — overload & SLO degradation (synthetic, primary slowed {SLOW_MS}ms/batch)\n"
    );
    let synth = SyntheticServing::build("ovbench");
    // Deterministic capacity: the primary sleeps SLOW_MS per batch, so
    // with batches of up to OV_MAX_BATCH it serves ~this many req/s.
    faults::force_faults(&format!("slow:{}:{SLOW_MS}ms", synth.baseline_target()));
    let capacity = OV_MAX_BATCH as f64 * 1000.0 / SLOW_MS as f64;
    println!(
        "primary capacity ~{capacity:.0} req/s; SLO attainment = completed within {ATTAIN_MS}ms\n"
    );
    println!("| degrade | offered | goodput | shed% | timeout% | attainment | p50 | p95 | primary/fallback |");
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut points = Vec::new();
    for degrade in [false, true] {
        for mult in [0.5, 1.0, 2.0, 4.0] {
            let p = overload_point(&synth, degrade, mult, capacity)?;
            println!(
                "| {} | {:.1}x ({:.0}/s) | {:.0}/s | {:.1}% | {:.1}% | {:.3} | {:.1}ms | {:.1}ms | {}/{} |",
                if p.degrade { "on" } else { "off" },
                p.mult,
                p.offered_rate,
                p.goodput,
                100.0 * p.shed as f64 / p.submitted.max(1) as f64,
                100.0 * p.timed_out as f64 / p.submitted.max(1) as f64,
                p.attainment,
                p.p50_ms,
                p.p95_ms,
                p.primary_served,
                p.fallback_served,
            );
            points.push(p);
        }
    }
    faults::clear_faults(&synth.baseline_target());
    synth.cleanup();

    let mut points_json = String::new();
    for p in &points {
        if !points_json.is_empty() {
            points_json.push_str(",\n    ");
        }
        points_json.push_str(&format!(
            "{{\"degrade\": {}, \"overload\": {}, \"offered_rate\": {:.1}, \
             \"submitted\": {}, \"completed\": {}, \"shed\": {}, \"timed_out\": {}, \
             \"failed\": {}, \"goodput\": {:.1}, \"slo_attainment\": {:.4}, \
             \"p50_ms\": {:.2}, \"p95_ms\": {:.2}, \
             \"served_primary\": {}, \"served_fallback\": {}}}",
            p.degrade,
            p.mult,
            p.offered_rate,
            p.submitted,
            p.completed,
            p.shed,
            p.timed_out,
            p.failed,
            p.goodput,
            p.attainment,
            p.p50_ms,
            p.p95_ms,
            p.primary_served,
            p.fallback_served,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"overload\",\n  \"slow_ms\": {SLOW_MS},\n  \
         \"capacity_rps\": {capacity:.1},\n  \"attain_ms\": {ATTAIN_MS},\n  \
         \"points\": [\n    {points_json}\n  ]\n}}\n"
    );
    match std::fs::write("BENCH_overload.json", &json) {
        Ok(()) => println!("\nwrote BENCH_overload.json"),
        Err(e) => println!("\ncould not write BENCH_overload.json: {e}"),
    }

    // The tentpole claim: at the top overload point, degradation must
    // improve SLO attainment over serving the slow primary alone.
    let top_off = points
        .iter()
        .find(|p| !p.degrade && p.mult == 4.0)
        .expect("off point");
    let top_on = points
        .iter()
        .find(|p| p.degrade && p.mult == 4.0)
        .expect("on point");
    println!(
        "\nSLO attainment at 4x overload: off={:.3} on={:.3} — {}",
        top_off.attainment,
        top_on.attainment,
        if top_on.attainment >= top_off.attainment { "IMPROVED (or equal)" } else { "REGRESSED" }
    );
    assert!(
        top_on.attainment >= top_off.attainment,
        "degradation must not reduce SLO attainment under overload \
         (on={:.3} off={:.3})",
        top_on.attainment,
        top_off.attainment
    );
    Ok(())
}

fn a1_policy_ablation(registry: Registry) -> anyhow::Result<()> {
    let (images, _) = registry.val_set()?;
    println!("# A1 — batcher policy ablation (vit/perlayer_64, {DURATION_S}s per point)\n");
    println!("| policy | rate | p50 | p99 | throughput | mean batch |");
    println!("|---|---|---|---|---|---|");
    for policy in [BatchPolicy::SizeOnly, BatchPolicy::Deadline, BatchPolicy::Adaptive] {
        // One server per policy so metrics are isolated.
        let server = Server::start(ServerConfig {
            artifacts_dir: "artifacts".into(),
            backend: clusterformer::runtime::BackendKind::from_env()?,
            targets: vec![(
                "vit".to_string(),
                VariantKey::Clustered {
                    scheme: ClusterScheme::PerLayer,
                    clusters: 64,
                },
            )],
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(15),
                policy,
                queue_cap: 4096,
            },
            threads: clusterformer::runtime::ThreadBudget::from_env(),
            resilience: Default::default(),
        })?;
        let router = Arc::new(server.router.clone());
        for rate in [15.0, 60.0, 150.0] {
            let mut rng = Pcg32::new(99);
            let mut pending = Vec::new();
            let t0 = Instant::now();
            let mut n = 0usize;
            while t0.elapsed().as_secs_f64() < DURATION_S {
                std::thread::sleep(Duration::from_secs_f64(
                    rng.exponential(rate).min(0.5),
                ));
                let row = n % images.shape()[0];
                let mut img = images.slice_rows(row, row + 1)?;
                let s = img.shape()[1..].to_vec();
                img.reshape(s)?;
                pending.push(router.submit("vit/perlayer_64", img)?.1);
                n += 1;
            }
            let mut lat: Vec<f64> = Vec::new();
            // Short timeout: under SizeOnly the final partial batch is
            // (by design) stuck until shutdown — don't wait a minute per
            // stranded request, just count it out of the throughput.
            for rx in pending {
                if let Ok(r) = rx.recv_timeout(Duration::from_secs(3)) {
                    if !r.logits.is_empty() {
                        lat.push(r.latency_s);
                    }
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            lat.sort_by(|a, b| a.total_cmp(b));
            let snap = server.snapshot();
            let mean_batch = snap
                .per_variant
                .values()
                .map(|v| v.mean_batch_size())
                .next()
                .unwrap_or(0.0);
            println!(
                "| {:?} | {:.0}/s | {:.2}ms | {:.2}ms | {:.1}/s | {:.2} |",
                policy,
                rate,
                percentile_sorted(&lat, 0.5) * 1e3,
                percentile_sorted(&lat, 0.99) * 1e3,
                lat.len() as f64 / wall,
                mean_batch,
            );
        }
        server.shutdown();
    }
    println!(
        "\nexpected shape: SizeOnly has pathological tail latency at low rate \
         (batches never fill); Adaptive matches Deadline's tail while \
         forming larger batches at high rate."
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    overload_sweep()?;
    println!();
    match Registry::load("artifacts") {
        Ok(registry) => a1_policy_ablation(registry)?,
        Err(_) => println!(
            "skipping A1 policy ablation: no artifacts/ (run `make artifacts`)"
        ),
    }
    Ok(())
}
