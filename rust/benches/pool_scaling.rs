//! Pool-scaling microbench (ISSUE 4): the persistent kernel pool against
//! the retired per-call `std::thread::scope` spawn strategy, on repeated
//! serving-shaped dots (no artifacts needed).
//!
//! Three regimes:
//! * `small` — a batch-1-sized dot below the parallel-work threshold:
//!   both strategies run the identical serial microkernel, so pooled
//!   execution must be no slower;
//! * `medium` — a ViT-block-shaped dot above the threshold, repeated
//!   per inference step: the scoped baseline pays a thread spawn/join
//!   round-trip per call, the pool only a queue push + latch;
//! * `lut` — the clustered LUT matmul, serial vs pooled fan-out.
//!
//! Every pooled result is cross-checked bit-for-bit against the scoped
//! baseline so a broken fan-out cannot silently post a win.

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::runtime::interp::clustered::{lut_matmul_packed, prepare};
use clusterformer::runtime::interp::gemm::{gemm, gemm_rows, Tile};
use clusterformer::runtime::interp::pool_exec::pool_workers;
use clusterformer::runtime::ThreadBudget;
use clusterformer::util::rng::Pcg32;

/// The retired strategy, kept verbatim as the bench baseline (including
/// its work threshold): spawn and join scoped threads inside every call.
fn gemm_scoped(m: usize, k: usize, n: usize, a: &[f32], w: &[f32], out: &mut [f32], threads: usize) {
    const PAR_MIN_FLOPS: usize = 1 << 20;
    let tile = Tile { m, k, n };
    let nt = threads.min(m);
    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if nt <= 1 || flops < PAR_MIN_FLOPS {
        gemm_rows(0, m, tile, a, w, out);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, out_chunk) in out.chunks_mut(chunk * n).enumerate() {
            let nrows = out_chunk.len() / n;
            s.spawn(move || gemm_rows(ci * chunk, nrows, tile, a, w, out_chunk));
        }
    });
}

struct Case {
    name: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

fn main() -> anyhow::Result<()> {
    let threads = ThreadBudget::from_env().get();
    let mut rng = Pcg32::new(4_2021);
    println!(
        "# pool scaling — budget {threads}, {} pool workers\n",
        pool_workers()
    );
    let mut runner = BenchRunner::new(BenchConfig::default());

    let cases = [
        // batch=1 single token row block: below PAR_MIN_FLOPS, serial in
        // both strategies — parity check.
        Case { name: "small", m: 16, k: 64, n: 64 },
        // ViT-block-shaped: above the threshold, both strategies fan out.
        Case { name: "medium", m: 197, k: 192, n: 192 },
        Case { name: "large", m: 256, k: 256, n: 256 },
    ];
    println!("| case | scoped-spawn | pooled | pooled speedup |");
    println!("|---|---|---|---|");
    let mut medium_speedup = 1.0f64;
    for case in &cases {
        let (m, k, n) = (case.m, case.k, case.n);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut out_scoped = vec![0.0f32; m * n];
        let mut out_pooled = vec![0.0f32; m * n];

        let scoped = runner
            .bench(&format!("gemm/{}/scoped", case.name), || {
                out_scoped.fill(0.0);
                gemm_scoped(m, k, n, &a, &w, &mut out_scoped, threads);
            })
            .summary
            .mean;
        let pooled = runner
            .bench(&format!("gemm/{}/pooled", case.name), || {
                out_pooled.fill(0.0);
                gemm(1, m, k, n, &a, &w, &mut out_pooled, threads);
            })
            .summary
            .mean;
        // Rerun once outside the timer so the comparison buffers hold the
        // final kernels' output, then cross-check bit-for-bit.
        out_scoped.fill(0.0);
        gemm_scoped(m, k, n, &a, &w, &mut out_scoped, threads);
        out_pooled.fill(0.0);
        gemm(1, m, k, n, &a, &w, &mut out_pooled, threads);
        assert_eq!(out_scoped, out_pooled, "{}: pooled GEMM diverged", case.name);

        println!(
            "| gemm {} ({m}x{k}x{n}) | {} | {} | {:.2}x |",
            case.name,
            fmt_time(scoped),
            fmt_time(pooled),
            scoped / pooled
        );
        if case.name == "medium" {
            medium_speedup = scoped / pooled;
        }
    }

    // LUT matmul: serial vs pooled fan-out on 64-cluster packed weights.
    let (m, k, n, clusters) = (197usize, 192usize, 192usize, 64usize);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let cb: Vec<f32> = (0..clusters).map(|_| rng.normal() as f32).collect();
    let idx: Vec<u8> = (0..k * n).map(|_| rng.range(0, clusters - 1) as u8).collect();
    let prep = prepare(&idx, k, n, &cb, Some(clusters))?;
    let serial = runner
        .bench("lut/serial", || lut_matmul_packed(&x, m, &prep, 1).unwrap())
        .summary
        .mean;
    let pooled_lut = runner
        .bench("lut/pooled", || lut_matmul_packed(&x, m, &prep, threads).unwrap())
        .summary
        .mean;
    assert_eq!(
        lut_matmul_packed(&x, m, &prep, 1)?,
        lut_matmul_packed(&x, m, &prep, threads)?,
        "pooled LUT diverged"
    );
    println!(
        "| lut ({m}x{k}x{n}, c={clusters}) | {} (serial) | {} | {:.2}x |",
        fmt_time(serial),
        fmt_time(pooled_lut),
        serial / pooled_lut
    );

    println!(
        "\npooled vs scoped on repeated medium dots: {:.2}x (target >= 1.0x: {})",
        medium_speedup,
        if medium_speedup >= 1.0 { "MET" } else { "NOT met" }
    );
    runner.finish("pool scaling");
    Ok(())
}
