//! §Perf instrumentation (EXPERIMENTS.md §Perf):
//!
//! * L2 — kernel-path (interpret-Pallas while-loops) vs refpath (plain
//!   jnp, XLA-fused) module wall time and fusion counts on the CPU PJRT
//!   backend;
//! * L3 — resident-weights executable vs re-uploading weights per call;
//! * L3 — coordinator overhead: through-server round trip vs raw
//!   executor call at the same batch size.

use std::time::{Duration, Instant};

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::worker::VariantExecutor;
use clusterformer::coordinator::{
    BatchPolicy, BatcherConfig, Server, ServerConfig,
};
use clusterformer::hlo::{CostAnalysis, HloModule};
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::{default_backend, Backend as _, Executor as _, ResidentExecutor as _};

fn main() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let mut registry = Registry::load("artifacts")?;
    let (images, _) = registry.val_set()?;
    let batch8 = images.slice_rows(0, 8)?;
    let mut runner = BenchRunner::new(BenchConfig::heavy());

    println!("# §Perf measurements\n");

    // ---- L2: kernel path vs XLA-fused refpath --------------------------
    println!("## L2: interpret-Pallas kernel path vs XLA-fused refpath (batch 8, CPU)\n");
    let variant = registry.variant("vit", VariantKey::Baseline)?;
    let clustered_variant = registry.variant(
        "vit",
        VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
    )?;
    let mut l2_rows = Vec::new();
    for (label, file, inputs) in [
        (
            "baseline/kernelpath",
            "artifacts/vit_8_baseline.hlo.txt".to_string(),
            {
                let mut v = vec![batch8.clone()];
                v.extend(variant.weight_inputs.iter().cloned());
                v
            },
        ),
        (
            "baseline/refpath",
            "artifacts/vit_8_refpath.hlo.txt".to_string(),
            {
                let mut v = vec![batch8.clone()];
                v.extend(variant.weight_inputs.iter().cloned());
                v
            },
        ),
        (
            "clustered/kernelpath",
            "artifacts/vit_8_clustered.hlo.txt".to_string(),
            {
                let mut v = vec![batch8.clone()];
                v.extend(clustered_variant.weight_inputs.iter().cloned());
                v
            },
        ),
        (
            "clustered/refpath",
            "artifacts/vit_8_clustered_refpath.hlo.txt".to_string(),
            {
                let mut v = vec![batch8.clone()];
                v.extend(clustered_variant.weight_inputs.iter().cloned());
                v
            },
        ),
    ] {
        let module = HloModule::parse_file(&file)?;
        let cost = CostAnalysis::of(&module)?;
        let n_instr: usize = cost.opcode_counts.values().sum();
        let exe = backend.load_hlo(std::path::Path::new(&file))?;
        let r = runner.bench_items(label, 8.0, || exe.run(&inputs).unwrap());
        l2_rows.push((label, r.summary.mean, n_instr, cost.fusion_count()));
    }
    println!("\n| module | mean | instructions | fusions |\n|---|---|---|---|");
    for (label, mean, n, fus) in &l2_rows {
        println!("| {label} | {} | {n} | {fus} |", fmt_time(*mean));
    }
    println!(
        "\nkernel-path / refpath slowdown: baseline {:.2}x, clustered {:.2}x \
         (the price of interpret-mode grid loops on CPU; on real TPU the \
         kernel path is the optimized one — see the structural L1 report)\n",
        l2_rows[0].1 / l2_rows[1].1,
        l2_rows[2].1 / l2_rows[3].1
    );

    // ---- L3: resident weights vs per-call upload ------------------------
    println!("## L3: resident device weights vs per-call weight upload (batch 8)\n");
    let exe = backend.load_hlo(std::path::Path::new("artifacts/vit_8_baseline.hlo.txt"))?;
    let resident =
        exe.with_resident(1, std::sync::Arc::new(variant.weight_inputs.clone()))?;
    resident.warmup()?;
    let mut full_inputs = vec![batch8.clone()];
    full_inputs.extend(variant.weight_inputs.iter().cloned());
    let r_upload = runner
        .bench_items("upload-weights-per-call", 8.0, || exe.run(&full_inputs).unwrap())
        .summary
        .mean;
    let r_resident = runner
        .bench_items("resident-weights", 8.0, || {
            resident.run(std::slice::from_ref(&batch8)).unwrap()
        })
        .summary
        .mean;
    println!(
        "\nresident weights save {:.1}% per call ({} -> {})\n",
        (1.0 - r_resident / r_upload) * 100.0,
        fmt_time(r_upload),
        fmt_time(r_resident)
    );

    // ---- L3: coordinator overhead ---------------------------------------
    println!("## L3: coordinator overhead vs raw executor (batch 8, closed loop)\n");
    let exec = VariantExecutor::load(
        backend.as_ref(),
        &mut registry,
        "vit",
        VariantKey::Baseline,
    )?;
    exec.warmup(&[8])?;
    let raw = runner
        .bench_items("raw-executor-batch8", 8.0, || exec.execute(&batch8).unwrap())
        .summary
        .mean;

    let server = Server::start(ServerConfig {
        artifacts_dir: "artifacts".into(),
        targets: vec![("vit".to_string(), VariantKey::Baseline)],
        backend: clusterformer::runtime::BackendKind::from_env()?,
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
            policy: BatchPolicy::SizeOnly, // force full batches
            queue_cap: 64,
        },
        threads: clusterformer::runtime::ThreadBudget::from_env(),
        resilience: Default::default(),
    })?;
    let mut through = Vec::new();
    for _ in 0..20 {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let mut img = images.slice_rows(i, i + 1).unwrap();
                let s = img.shape()[1..].to_vec();
                img.reshape(s).unwrap();
                server.router.submit("vit/baseline", img).unwrap().1
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        through.push(t0.elapsed().as_secs_f64());
    }
    server.shutdown();
    let through_mean = through.iter().sum::<f64>() / through.len() as f64;
    println!(
        "raw batch-8 execute: {} | through coordinator: {} | overhead {:.1}%\n",
        fmt_time(raw),
        fmt_time(through_mean),
        (through_mean / raw - 1.0) * 100.0
    );
    println!(
        "target: coordinator overhead <5% of a batch execute — {}",
        if through_mean / raw < 1.05 { "MET" } else { "NOT met (see §Perf log)" }
    );
    runner.finish("perf pass");
    Ok(())
}
