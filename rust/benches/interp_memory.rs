//! Memory-planner bench: unplanned vs planned execution over an
//! attention-shaped synthetic ViT module (no artifacts needed).
//!
//! * `unplanned` — the classic evaluator: one fresh buffer per
//!   instruction, operands cloned on the reshape/tuple paths;
//! * `planned`   — the arena executor: liveness-reused slots, in-place
//!   elementwise, zero-copy reshape, kernels writing into planned slots,
//!   and (since ISSUE 5) fused elementwise chains / GEMM epilogues / the
//!   fused row softmax.
//!
//! Besides wall time, reports the quantities the paper's memory argument
//! is about: peak resident intermediate bytes (sum of planned slot
//! capacities) vs the unplanned sum of all instruction buffers, and
//! tensor-sized allocation counts per inference.
//! Acceptance targets (ISSUE 3): planned peak <= 50% of unplanned sum;
//! planned steady-state allocations = 0. The fusion-specific A/B
//! (fused vs unfused plans, ISSUE 5) lives in `benches/fusion.rs`.

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::{
    evaluate_unplanned, force_verify_mode, stats, InterpExecutor, VerifyMode,
};
use clusterformer::runtime::Executor as _;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::{vit_shaped_hlo, vit_shaped_inputs};
use clusterformer::util::rng::Pcg32;

/// Tokens x head dim of the synthetic activations (serving-shaped:
/// m >> d, so the `[m, m]` attention scores dominate the intermediates).
const M: usize = 128;
const D: usize = 16;
const LAYERS: usize = 4;

fn main() -> anyhow::Result<()> {
    let hlo = vit_shaped_hlo(M, D, LAYERS);
    let module = HloModule::parse(&hlo)?;
    let exe = InterpExecutor::load_text(&hlo, "vit-shaped")?;
    let mem = exe
        .memory_plan()
        .expect("the ViT-shaped module must be plannable");

    let mut rng = Pcg32::new(31 * 2106);
    let inputs = vit_shaped_inputs(M, D, LAYERS, &mut rng);
    let refs: Vec<&Tensor> = inputs.iter().collect();

    // Correctness anchor before timing. The fused softmax is not
    // bit-identical to the classic lowering by construction, so the
    // planned path is checked against the unplanned reference with a
    // tight relative tolerance here; the exact <= 4 ULP contract is
    // property-tested in tests/fusion_props.rs.
    let planned_out = exe.run(&inputs)?;
    let unplanned_out = evaluate_unplanned(&module, &refs)?;
    let (p, u) = (planned_out[0].as_f32()?, unplanned_out[0].as_f32()?);
    assert_eq!(p.len(), u.len());
    for (a, b) in p.iter().zip(&u) {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "planned diverged from unplanned: {a} vs {b}"
        );
    }

    // Allocation counts per inference (planned is warm after the run
    // above, so its steady state should be exactly zero).
    let before = stats::tensor_allocs();
    exe.run(&inputs)?;
    let planned_allocs = stats::tensor_allocs() - before;
    let before = stats::tensor_allocs();
    evaluate_unplanned(&module, &refs)?;
    let unplanned_allocs = stats::tensor_allocs() - before;

    println!(
        "# Interpreter memory planning — {LAYERS} attention layers of [{M},{D}] (ViT-shaped)\n"
    );
    let mut runner = BenchRunner::new(BenchConfig::default());
    let unplanned = runner
        .bench("exec/unplanned", || evaluate_unplanned(&module, &refs).unwrap())
        .summary
        .mean;
    let planned = runner
        .bench("exec/planned-arena", || exe.run(&inputs).unwrap())
        .summary
        .mean;

    let peak = mem.peak_bytes();
    let naive = mem.naive_bytes();
    println!("\n| path | mean | intermediate bytes | allocs/inference |");
    println!("|---|---|---|---|");
    println!("| unplanned | {} | {naive} | {unplanned_allocs} |", fmt_time(unplanned));
    println!(
        "| planned ({} slots, {} fused chains / {} epilogues / {} softmax) | {} | {peak} | {planned_allocs} |",
        mem.slot_count(),
        mem.fused_chains(),
        mem.fused_epilogues(),
        mem.fused_softmax(),
        fmt_time(planned)
    );
    println!(
        "\nplanned peak vs unplanned sum: {:.1}% (target <= 50%: {})",
        100.0 * peak as f64 / naive.max(1) as f64,
        if peak * 2 <= naive { "PASS" } else { "FAIL" }
    );
    println!(
        "planned steady-state allocations: {planned_allocs} (target 0: {})",
        if planned_allocs == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "fused_bytes_saved per inference: {} ({:.1}% of unfused write+read traffic)",
        mem.fused_bytes_saved(),
        100.0 * mem.fused_bytes_saved() as f64 / (2 * naive).max(1) as f64
    );
    println!(
        "speedup planned vs unplanned: {:.2}x",
        unplanned / planned
    );

    // Bind-time cost of the plan verifier (ISSUE 9): rebuild the
    // executor with verification forced off vs on inside this process
    // (the env knob resolves once, so the A/B goes through the forced
    // override). Verification runs at bind only — steady-state execution
    // cost is zero by construction — so the acceptance target is on the
    // bind itself: <= 10% overhead.
    println!("\n# Plan verifier bind overhead\n");
    force_verify_mode(Some(VerifyMode::Off));
    let bind_off = runner
        .bench("bind/verify-off", || {
            InterpExecutor::load_text(&hlo, "vit-shaped-verify-off").unwrap()
        })
        .summary
        .mean;
    force_verify_mode(Some(VerifyMode::On));
    let bind_on = runner
        .bench("bind/verify-on", || {
            InterpExecutor::load_text(&hlo, "vit-shaped-verify-on").unwrap()
        })
        .summary
        .mean;
    force_verify_mode(None);
    println!("\n| bind | mean |");
    println!("|---|---|");
    println!("| verify off | {} |", fmt_time(bind_off));
    println!("| verify on | {} |", fmt_time(bind_on));
    println!(
        "verify-on bind overhead: {:+.1}% (target <= 10%: {})",
        100.0 * (bind_on - bind_off) / bind_off.max(1e-12),
        if bind_on <= bind_off * 1.10 { "PASS" } else { "FAIL" }
    );
    Ok(())
}
