//! Memory-planner bench: unplanned vs planned execution over a
//! ViT-shaped synthetic HLO module (no artifacts needed).
//!
//! * `unplanned` — the classic evaluator: one fresh buffer per
//!   instruction, operands cloned on the reshape/tuple paths;
//! * `planned`   — the arena executor: liveness-reused slots, in-place
//!   elementwise, zero-copy reshape, kernels writing into planned slots.
//!
//! Besides wall time, reports the quantities the paper's memory argument
//! is about: peak resident intermediate bytes (sum of planned slot
//! capacities) vs the unplanned sum of all instruction buffers, and
//! tensor-sized allocation counts per inference.
//! Acceptance targets (ISSUE 3): planned peak <= 50% of unplanned sum;
//! planned steady-state allocations = 0.

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::{evaluate_unplanned, stats, InterpExecutor};
use clusterformer::runtime::Executor as _;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::vit_shaped_hlo;
use clusterformer::util::rng::Pcg32;

/// Tokens x model dim of the synthetic activations.
const M: usize = 64;
const D: usize = 64;
const LAYERS: usize = 6;

fn main() -> anyhow::Result<()> {
    let hlo = vit_shaped_hlo(M, D, LAYERS);
    let module = HloModule::parse(&hlo)?;
    let exe = InterpExecutor::load_text(&hlo, "vit-shaped")?;
    let mem = exe
        .memory_plan()
        .expect("the ViT-shaped module must be plannable");

    let mut rng = Pcg32::new(31 * 2106);
    let mut inputs = Vec::new();
    inputs.push(Tensor::from_f32(
        vec![M, D],
        &(0..M * D).map(|_| rng.normal() as f32 * 0.2).collect::<Vec<_>>(),
    )?);
    for _ in 0..LAYERS {
        for _ in 0..2 {
            inputs.push(Tensor::from_f32(
                vec![D, D],
                &(0..D * D).map(|_| rng.normal() as f32 * 0.1).collect::<Vec<_>>(),
            )?);
        }
    }
    let refs: Vec<&Tensor> = inputs.iter().collect();

    // Correctness anchor before timing: bit-for-bit equal paths.
    let planned_out = exe.run(&inputs)?;
    let unplanned_out = evaluate_unplanned(&module, &refs)?;
    assert_eq!(planned_out, unplanned_out, "planned must match unplanned");

    // Allocation counts per inference (planned is warm after the run
    // above, so its steady state should be exactly zero).
    let before = stats::tensor_allocs();
    exe.run(&inputs)?;
    let planned_allocs = stats::tensor_allocs() - before;
    let before = stats::tensor_allocs();
    evaluate_unplanned(&module, &refs)?;
    let unplanned_allocs = stats::tensor_allocs() - before;

    println!(
        "# Interpreter memory planning — {LAYERS} layers of [{M},{D}] (ViT-shaped)\n"
    );
    let mut runner = BenchRunner::new(BenchConfig::default());
    let unplanned = runner
        .bench("exec/unplanned", || evaluate_unplanned(&module, &refs).unwrap())
        .summary
        .mean;
    let planned = runner
        .bench("exec/planned-arena", || exe.run(&inputs).unwrap())
        .summary
        .mean;

    let peak = mem.peak_bytes();
    let naive = mem.naive_bytes();
    println!("\n| path | mean | intermediate bytes | allocs/inference |");
    println!("|---|---|---|---|");
    println!("| unplanned | {} | {naive} | {unplanned_allocs} |", fmt_time(unplanned));
    println!(
        "| planned ({} slots) | {} | {peak} | {planned_allocs} |",
        mem.slot_count(),
        fmt_time(planned)
    );
    println!(
        "\nplanned peak vs unplanned sum: {:.1}% (target <= 50%: {})",
        100.0 * peak as f64 / naive.max(1) as f64,
        if peak * 2 <= naive { "PASS" } else { "FAIL" }
    );
    println!(
        "planned steady-state allocations: {planned_allocs} (target 0: {})",
        if planned_allocs == 0 { "PASS" } else { "FAIL" }
    );
    println!(
        "speedup planned vs unplanned: {:.2}x",
        unplanned / planned
    );
    Ok(())
}
