//! Open-loop load generation against the real HTTP front end: offered
//! load vs goodput with latency percentiles and shed/timeout/error
//! rates at each point.
//!
//! A synthetic single-variant server is slowed by a deterministic
//! injected fault so its capacity is known exactly; the generator then
//! drives 0.5x/1x/2x/4x that capacity through **real sockets** —
//! connection per request, JSON body, client-side `deadline_ms` — so
//! the measured path includes accept, parse, admission, batching, and
//! response write. Emits machine-readable `BENCH_serving.json`.
//!
//! Pacing: eight generator threads, each deficit-paced at its share of
//! the offered rate. A generator blocks while its one in-flight
//! request is being answered, so under deep overload the *realized*
//! offered rate falls short of nominal — both are reported, and the
//! client deadline keeps per-request stalls bounded, which is what
//! keeps the loop approximately open.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use std::io::{Read, Write};

use clusterformer::coordinator::{
    faults, BatchPolicy, BatcherConfig, HttpConfig, HttpServer, ResilienceConfig, Server,
    ServerConfig,
};
use clusterformer::model::VariantKey;
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::testing::synthetic::SyntheticServing;
use clusterformer::util::stats::percentile_sorted;

/// Injected per-batch execution time: with `MAX_BATCH` the worker's
/// capacity is exactly `MAX_BATCH * 1000 / SLOW_MS` req/s.
const SLOW_MS: u64 = 5;
const MAX_BATCH: usize = 4;
/// Seconds of offered load per point.
const POINT_S: f64 = 1.2;
/// Generator threads (one in-flight request each).
const CLIENTS: usize = 8;
/// Client-side deadline carried in each request body.
const DEADLINE_MS: u64 = 150;

#[derive(Default)]
struct Tally {
    ok: usize,
    shed: usize,     // 429
    timeout: usize,  // 504
    error: usize,    // other 5xx
    conn_err: usize, // torn / refused / unparseable
    lat_ms: Vec<f64>,
}

struct Point {
    mult: f64,
    nominal_rate: f64,
    realized_rate: f64,
    submitted: usize,
    tally: Tally,
    goodput: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
}

/// One request over its own connection; returns (status, latency).
/// Status 0 means the connection failed or the response was torn.
fn one_request(addr: SocketAddr, body: &str) -> (u16, f64) {
    let t0 = Instant::now();
    let run = || -> std::io::Result<u16> {
        let mut s = std::net::TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        s.set_read_timeout(Some(Duration::from_secs(30)))?;
        let raw = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        s.write_all(raw.as_bytes())?;
        let mut text = String::new();
        s.read_to_string(&mut text)?;
        Ok(text
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse::<u16>().ok())
            .unwrap_or(0))
    };
    let status = run().unwrap_or(0);
    (status, t0.elapsed().as_secs_f64() * 1e3)
}

fn load_point(synth: &SyntheticServing, mult: f64, capacity: f64) -> anyhow::Result<Point> {
    let server = Server::start(ServerConfig {
        artifacts_dir: synth.dir.clone(),
        targets: vec![(synth.model.clone(), VariantKey::Baseline)],
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(2),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        threads: ThreadBudget::new(2),
        resilience: ResilienceConfig { queue_bound: 64, ..ResilienceConfig::default() },
    })?;
    let http = HttpServer::start(
        server.router.clone(),
        server.metrics.clone(),
        HttpConfig {
            max_conns: 512,
            label: "loadbench-fe".to_string(),
            ..HttpConfig::default()
        },
    )?;
    let addr = http.addr();

    let nominal_rate = capacity * mult;
    let per_thread = nominal_rate / CLIENTS as f64;
    let target = synth.baseline_target();
    let img = SyntheticServing::image(1).as_f32()?;
    let vals: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    let body = format!(
        "{{\"target\":\"{target}\",\"shape\":[2,2,3],\"image\":[{}],\"deadline_ms\":{DEADLINE_MS}}}",
        vals.join(",")
    );

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..CLIENTS {
        let body = body.clone();
        joins.push(std::thread::spawn(move || {
            let mut t = Tally::default();
            let mut sent = 0usize;
            let t0 = Instant::now();
            loop {
                let elapsed = t0.elapsed().as_secs_f64();
                if elapsed >= POINT_S {
                    return (sent, t);
                }
                let due = (elapsed * per_thread) as usize;
                if sent >= due {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                let (status, lat) = one_request(addr, &body);
                sent += 1;
                match status {
                    200 => {
                        t.ok += 1;
                        t.lat_ms.push(lat);
                    }
                    429 => t.shed += 1,
                    504 => t.timeout += 1,
                    s if s >= 500 => t.error += 1,
                    _ => t.conn_err += 1,
                }
            }
        }));
    }
    let mut submitted = 0usize;
    let mut tally = Tally::default();
    for j in joins {
        let (sent, t) = j.join().expect("generator thread");
        submitted += sent;
        tally.ok += t.ok;
        tally.shed += t.shed;
        tally.timeout += t.timeout;
        tally.error += t.error;
        tally.conn_err += t.conn_err;
        tally.lat_ms.extend(t.lat_ms);
    }
    let wall = t0.elapsed().as_secs_f64();
    http.shutdown();
    server.shutdown();

    tally.lat_ms.sort_by(|a, b| a.total_cmp(b));
    let pctl = |q| {
        if tally.lat_ms.is_empty() { 0.0 } else { percentile_sorted(&tally.lat_ms, q) }
    };
    let (p50_ms, p99_ms, p999_ms) = (pctl(0.5), pctl(0.99), pctl(0.999));
    Ok(Point {
        mult,
        nominal_rate,
        realized_rate: submitted as f64 / wall,
        submitted,
        goodput: tally.ok as f64 / wall,
        p50_ms,
        p99_ms,
        p999_ms,
        tally,
    })
}

fn main() -> anyhow::Result<()> {
    println!("# serving load — offered vs goodput through the HTTP front end\n");
    let synth = SyntheticServing::build("loadbench");
    let target = synth.baseline_target();
    faults::force_faults(&format!("slow:{target}:{SLOW_MS}ms"));
    let capacity = MAX_BATCH as f64 * 1000.0 / SLOW_MS as f64;
    println!(
        "worker capacity ~{capacity:.0} req/s (slow fault {SLOW_MS}ms/batch, \
         max_batch {MAX_BATCH}); deadline {DEADLINE_MS}ms; {CLIENTS} generators, \
         {POINT_S}s per point\n"
    );
    println!("| offered | realized | goodput | ok% | shed% | timeout% | err% | conn-err | p50 | p99 | p99.9 |");
    println!("|---|---|---|---|---|---|---|---|---|---|---|");

    let mut points = Vec::new();
    for mult in [0.5, 1.0, 2.0, 4.0] {
        let p = load_point(&synth, mult, capacity)?;
        let n = p.submitted.max(1) as f64;
        println!(
            "| {:.1}x ({:.0}/s) | {:.0}/s | {:.0}/s | {:.1}% | {:.1}% | {:.1}% | {:.1}% | {} | {:.1}ms | {:.1}ms | {:.1}ms |",
            p.mult,
            p.nominal_rate,
            p.realized_rate,
            p.goodput,
            100.0 * p.tally.ok as f64 / n,
            100.0 * p.tally.shed as f64 / n,
            100.0 * p.tally.timeout as f64 / n,
            100.0 * p.tally.error as f64 / n,
            p.tally.conn_err,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
        );
        points.push(p);
    }
    faults::clear_faults(&target);
    synth.cleanup();

    let mut points_json = String::new();
    for p in &points {
        if !points_json.is_empty() {
            points_json.push_str(",\n    ");
        }
        points_json.push_str(&format!(
            "{{\"overload\": {}, \"nominal_rate\": {:.1}, \"realized_rate\": {:.1}, \
             \"submitted\": {}, \"ok\": {}, \"shed\": {}, \"timeout\": {}, \
             \"error\": {}, \"conn_err\": {}, \"goodput\": {:.1}, \
             \"p50_ms\": {:.2}, \"p99_ms\": {:.2}, \"p999_ms\": {:.2}}}",
            p.mult,
            p.nominal_rate,
            p.realized_rate,
            p.submitted,
            p.tally.ok,
            p.tally.shed,
            p.tally.timeout,
            p.tally.error,
            p.tally.conn_err,
            p.goodput,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"serving_load\",\n  \"slow_ms\": {SLOW_MS},\n  \
         \"capacity_rps\": {capacity:.1},\n  \"deadline_ms\": {DEADLINE_MS},\n  \
         \"clients\": {CLIENTS},\n  \"point_s\": {POINT_S},\n  \
         \"points\": [\n    {points_json}\n  ]\n}}\n"
    );
    match std::fs::write("BENCH_serving.json", &json) {
        Ok(()) => println!("\nwrote BENCH_serving.json"),
        Err(e) => println!("\ncould not write BENCH_serving.json: {e}"),
    }

    // Sanity, lenient on CI noise: below capacity the system mostly
    // serves; at deep overload the front end degrades by *typed
    // shedding* (429/504), not by connection failures.
    let under = &points[0];
    assert!(
        under.tally.ok * 2 > under.submitted,
        "at 0.5x capacity most requests must complete ({}/{} ok)",
        under.tally.ok,
        under.submitted
    );
    let over = points.last().expect("points");
    assert!(
        over.tally.shed + over.tally.timeout > 0,
        "at 4x capacity the front end must shed or time out some load"
    );
    assert_eq!(
        over.tally.conn_err, 0,
        "overload must surface as typed statuses, never torn connections"
    );
    Ok(())
}
