//! Fig. 3 reproduction: memory-usage breakdown of DeiT and ViT.
//!
//! The paper splits memory into "MatMul parameters" (>40% in both
//! models), softmax, and other layers. We account parameters from the
//! manifest and activation buffers *analytically at model shapes*
//! (batch 8) — the static HLO byte count is distorted by interpret-mode
//! Pallas loops (every while-iteration temp counted at full size), so
//! shapes-based accounting matches what a memory planner would allocate.

use clusterformer::model::Registry;

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    let batch = 8usize;
    println!("# Fig. 3 — memory-usage breakdown (batch {batch}, analytic activations)\n");
    for model in ["deit", "vit"] {
        let entry = registry.manifest.model(model)?;
        let cfg = &entry.config;
        let (b, t, d, h) = (
            batch as f64,
            cfg.n_tokens() as f64,
            cfg.dim as f64,
            cfg.heads as f64,
        );
        let depth = cfg.depth as f64;
        let mlp = (cfg.mlp_ratio * cfg.dim) as f64;
        let f = 4.0; // fp32 bytes

        let matmul_params = entry.clustered_param_bytes() as f64;
        let other_params =
            (entry.total_param_bytes() - entry.clustered_param_bytes()) as f64;
        // activation buffers per block, summed over blocks:
        let matmul_acts =
            depth * (3.0 * b * t * d + b * t * d + b * t * mlp + b * t * d) * f;
        let softmax_bufs = depth * 2.0 * b * h * t * t * f; // scores + probs
        let norm_bufs = (depth * 2.0 + 1.0) * b * t * d * f; // LN outputs
        let gelu_bufs = depth * b * t * mlp * f;
        let io = b * (cfg.img_size * cfg.img_size * 3) as f64 * f;
        let total = matmul_params
            + other_params
            + matmul_acts
            + softmax_bufs
            + norm_bufs
            + gelu_bufs
            + io;

        println!("## {model} (total accounted: {:.1} MB)\n", total / 1e6);
        println!("| component | MB | share |\n|---|---|---|");
        for (name, v) in [
            ("MatMul parameters", matmul_params),
            ("MatMul activations", matmul_acts),
            ("Softmax buffers", softmax_bufs),
            ("GELU buffers", gelu_bufs),
            ("Norm buffers", norm_bufs),
            ("Other parameters", other_params),
            ("Input images", io),
        ] {
            println!("| {name} | {:.2} | {:.1}% |", v / 1e6, v / total * 100.0);
        }
        let share = matmul_params / total;
        println!(
            "\npaper check: MatMul params {:.1}% of memory (paper: >40%): {}\n",
            share * 100.0,
            if share > 0.4 { "REPRODUCED" } else { "NOT reproduced" }
        );
        // Counterfactual with clustered-64 parameters:
        let clustered_total = total - matmul_params - other_params
            + entry.variant_bytes("perlayer_64")? as f64;
        println!(
            "with clustered-64 parameters the same footprint is {:.1} MB ({:.2}x smaller)\n",
            clustered_total / 1e6,
            total / clustered_total
        );
    }
    Ok(())
}
