//! Fig. 7 reproduction: DeiT top-1/top-5 accuracy vs number of clusters,
//! entire-model vs per-layer, through the Rust runtime (clustered HLO
//! with the in-kernel indirect fetch).

#[path = "accuracy_sweep.rs"]
mod accuracy_sweep;

fn main() -> anyhow::Result<()> {
    accuracy_sweep::run_sweep("deit", "Fig. 7", accuracy_sweep::sweep_n())
}
