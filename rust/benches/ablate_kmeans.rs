//! Ablation A3: K-means initialization and iteration count on real model
//! weights — quantization error (inertia) vs compression cost.

use clusterformer::bench::{BenchConfig, BenchRunner};
use clusterformer::clustering::{inertia, lloyd_1d, KmeansInit};
use clusterformer::model::Registry;

fn main() -> anyhow::Result<()> {
    let mut registry = Registry::load("artifacts")?;
    let entry = registry.manifest.model("vit")?.clone();
    let names = entry.clustered_names();
    let weights = registry.weights("vit")?;
    // Flatten all clustered parameters (the "entire" scheme's point set).
    let mut points = Vec::new();
    for n in &names {
        points.extend(weights[n].as_f32()?);
    }
    println!(
        "# A3 — k-means init/iteration ablation on {} scalar weights (vit)\n",
        points.len()
    );

    println!("| init | iters | per-point MSE | rel. to best |");
    println!("|---|---|---|---|");
    let mut rows = Vec::new();
    for (label, init) in [
        ("quantile", KmeansInit::Quantile),
        ("uniform", KmeansInit::Uniform),
        ("random", KmeansInit::Random { seed: 7 }),
    ] {
        for iters in [0usize, 5, 20, 40] {
            let c = lloyd_1d(&points, 64, iters, init)?;
            let mse = inertia(&points, &c) / points.len() as f64;
            rows.push((label.to_string(), iters, mse));
        }
    }
    let best = rows.iter().map(|r| r.2).fold(f64::INFINITY, f64::min);
    for (label, iters, mse) in &rows {
        println!("| {label} | {iters} | {mse:.3e} | {:.3}x |", mse / best);
    }

    let mut runner = BenchRunner::new(BenchConfig {
        min_iters: 3,
        max_iters: 10,
        ..Default::default()
    });
    for (label, init) in [
        ("quantile", KmeansInit::Quantile),
        ("random", KmeansInit::Random { seed: 7 }),
    ] {
        runner.bench(&format!("lloyd64/{label}/40iters"), || {
            lloyd_1d(&points, 64, 40, init).unwrap()
        });
    }
    runner.finish("a3 kmeans init");
    println!(
        "takeaway: quantile init converges in <=5 Lloyd iterations on \
         weight-shaped (near-Gaussian) data; random init needs the full \
         budget to match — deterministic quantile init is both cheaper \
         and reproducible, which is why the pipeline defaults to it."
    );
    Ok(())
}
