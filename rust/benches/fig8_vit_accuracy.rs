//! Fig. 8 reproduction: ViT top-1/top-5 accuracy vs number of clusters,
//! entire-model vs per-layer, through the Rust runtime.

#[path = "accuracy_sweep.rs"]
mod accuracy_sweep;

fn main() -> anyhow::Result<()> {
    accuracy_sweep::run_sweep("vit", "Fig. 8", accuracy_sweep::sweep_n())
}
