//! Ablation A2: sub-byte index packing (paper §III-B aside).
//!
//! The paper keeps 8-bit indices even for c<256 because sub-byte formats
//! complicate alignment. This bench quantifies the actual trade on real
//! index tensors: extra compression vs pack/unpack throughput.

use clusterformer::bench::{BenchConfig, BenchRunner};
use clusterformer::clustering::packing::{
    bits_for_clusters, pack_indices, packed_len, unpack_indices,
};
use clusterformer::clustering::ClusterScheme;
use clusterformer::model::Registry;

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    // Real index data: the largest clustered tensor of the ViT at c=32.
    let ct = registry.clustered("vit", ClusterScheme::PerLayer, 32)?;
    let name = ct
        .names
        .iter()
        .max_by_key(|n| ct.indices[*n].elems())
        .unwrap()
        .clone();
    let idx = ct.indices[&name].as_u8()?.to_vec();
    println!(
        "# A2 — index bit-width ablation on {name} ({} indices, c=32)\n",
        idx.len()
    );

    println!("| bits | bytes | vs u8 | fits c |");
    println!("|---|---|---|---|");
    for bits in [4u32, 5, 6, 8] {
        println!(
            "| {bits} | {} | {:.2}x | {} |",
            packed_len(idx.len(), bits),
            idx.len() as f64 / packed_len(idx.len(), bits) as f64,
            1usize << bits
        );
    }
    println!(
        "\nminimum bits for 32 clusters: {} (paper uses 8 anyway)\n",
        bits_for_clusters(32)
    );

    let mut runner = BenchRunner::new(BenchConfig::default());
    for bits in [5u32, 6, 8] {
        let packed = pack_indices(&idx, bits)?;
        runner.bench_items(&format!("pack/{bits}bit"), idx.len() as f64, || {
            pack_indices(&idx, bits).unwrap()
        });
        runner.bench_items(
            &format!("unpack/{bits}bit"),
            idx.len() as f64,
            || unpack_indices(&packed, idx.len(), bits).unwrap(),
        );
    }
    runner.finish("a2 bitwidth packing");
    println!(
        "takeaway: 5/6-bit packing buys 1.3-1.6x extra compression but the \
         unpack sits on the inference critical path — the paper's \
         alignment argument (§III-B) is the 8-bit row above."
    );
    Ok(())
}
