//! Fig. 9 reproduction: speedup and normalized energy of the clustered
//! model vs baseline on the three modeled platforms + the Ideal Case.
//!
//! Primary source: the analytical platform simulator (the paper itself
//! models these platforms). A measured CPU-runtime data point (wall time
//! of the clustered vs baseline HLO through PJRT) is reported alongside
//! as a sanity check on the direction of the effect.
//!
//! Paper expectations: 5-38% speedup, 22-39% energy savings under
//! bandwidth pressure; Conf-1 shows the largest energy saving; the ideal
//! accelerator approaches the traffic-reduction bound.

use clusterformer::bench::{BenchConfig, BenchRunner};
use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::worker::VariantExecutor;
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::default_backend;
use clusterformer::simulator::profile::build_sim;
use clusterformer::simulator::PlatformKind;

fn main() -> anyhow::Result<()> {
    let mut registry = Registry::load("artifacts")?;
    println!("# Fig. 9 — speedup and normalized energy (clustered-64 per-layer)\n");

    for model in ["vit", "deit"] {
        let sim = build_sim(&mut registry, model, ClusterScheme::PerLayer, 64)?;
        println!(
            "## {model}: {:.1} MFLOP/img, weights {:.2} MB -> {:.2} MB\n",
            sim.flops / 1e6,
            sim.baseline_weight_bytes / 1e6,
            sim.clustered_weight_bytes / 1e6
        );
        for contention in [0.0, 0.5, 0.8] {
            println!("### contention {:.0}% (paper runs under \"maximum pressure\")\n", contention * 100.0);
            println!("| platform | speedup | norm. energy | energy saving | ideal speedup |");
            println!("|---|---|---|---|---|");
            for kind in PlatformKind::all() {
                let r = sim.run(kind, contention);
                println!(
                    "| {} | {:.2}x | {:.2} | {:.1}% | {:.2}x |",
                    kind.name(),
                    r.speedup,
                    r.e_clustered.total() / r.e_baseline.total(),
                    r.energy_saving * 100.0,
                    r.ideal_speedup
                );
            }
            println!();
        }
        // paper checks at the stressed point
        let stressed: Vec<_> = PlatformKind::all()
            .into_iter()
            .map(|k| sim.run(k, 0.5))
            .collect();
        let all_speedup = stressed.iter().all(|r| r.speedup > 1.0);
        let conf1_best_energy = stressed[0].energy_saving
            >= stressed[1].energy_saving.max(stressed[2].energy_saving) - 1e-9;
        println!(
            "paper check: all platforms speed up under pressure: {}",
            if all_speedup { "REPRODUCED" } else { "NOT reproduced" }
        );
        println!(
            "paper check: Conf-1 has the largest energy saving (paper: 39% vs 22%/22%): {}\n",
            if conf1_best_energy { "REPRODUCED" } else { "NOT reproduced" }
        );
    }

    // Measured CPU data point: clustered vs baseline HLO wall time.
    println!("## measured CPU-runtime sanity point (batch 8)\n");
    let backend = default_backend()?;
    let (images, _) = registry.val_set()?;
    let batch = images.slice_rows(0, 8)?;
    let mut runner = BenchRunner::new(BenchConfig::heavy());
    for (label, key) in [
        ("vit/baseline", VariantKey::Baseline),
        (
            "vit/clustered64",
            VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
        ),
    ] {
        let exec = VariantExecutor::load(backend.as_ref(), &mut registry, "vit", key)?;
        runner.bench_items(label, 8.0, || exec.execute(&batch).unwrap());
    }
    let base = runner.results[0].summary.mean;
    let clus = runner.results[1].summary.mean;
    println!(
        "\nmeasured wall-time ratio baseline/clustered = {:.2}x (CPU PJRT; direction check only — the CPU client is not bandwidth-starved like the modeled platforms)\n",
        base / clus
    );
    runner.finish("fig9 measured cpu point");
    Ok(())
}
