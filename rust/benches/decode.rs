//! Autoregressive decode bench (ISSUE 7): prefill latency and decode
//! throughput for the KV-cached session against the recompute-per-step
//! baseline, across sequence lengths and weight bit widths (no
//! artifacts needed — single-layer attention fixtures, head dim 64).
//!
//! * `prefill`   — warm prompt pass through the bucketed plan cache
//!                 (includes seeding the step session's KV slots);
//! * `decode`    — steady-state token/s of [`DecodeSession::step`]: one
//!                 new token staged per call, KV prefix resident in
//!                 persistent arena slots;
//! * `recompute` — the no-cache baseline: every token re-runs the full
//!                 prefill over the whole prefix (through the *warm*
//!                 plan cache, so the gap measured is pure compute, not
//!                 rebind overhead);
//! * `dispatch`  — plan-cache hit vs a fresh bind of the same bucket.
//!
//! Emits machine-readable `BENCH_decode.json`.
//!
//! Acceptance targets: KV-cached decode >= 2x recompute-per-step at
//! seq >= 128; cache-hit dispatch >= 10x faster than a rebind. Both are
//! asserted, and the decode outputs are cross-checked against the
//! from-scratch prefill before anything is timed.

use std::sync::Arc;
use std::time::Instant;

use clusterformer::bench::fmt_time;
use clusterformer::clustering::ClusteredTensors;
use clusterformer::runtime::interp::decode::{DecodeModel, DecodeSession};
use clusterformer::runtime::interp::plan_cache::{BucketLadder, DynResident, ExecSource};
use clusterformer::runtime::interp::InterpExecutor;
use clusterformer::runtime::ThreadBudget;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::{
    decode_clustered, decode_clustered_inputs, decode_prefill_hlo, decode_step_hlo, decode_weights,
};
use clusterformer::util::rng::Pcg32;

const D: usize = 64;
const SEQ_LENS: [usize; 3] = [32, 128, 256];
const STEPS: usize = 16;

struct Variant {
    name: String,
    bits: u32,
    fixed: Arc<Vec<Tensor>>,
    clustered: Option<Arc<ClusteredTensors>>,
}

fn scalar(v: usize) -> Tensor {
    Tensor::from_f32(vec![], &[v as f32]).unwrap()
}

fn rand_tokens(n: usize, rng: &mut Pcg32) -> Tensor {
    let vals: Vec<f32> = (0..n * D).map(|_| rng.normal() as f32 * 0.3).collect();
    Tensor::from_f32(vec![n, D], &vals).unwrap()
}

fn make_session(v: &Variant, threads: ThreadBudget) -> DecodeSession {
    let is_clustered = v.clustered.is_some();
    let model = DecodeModel {
        label: format!("bench/{}", v.name),
        dim: D,
        weights: v.fixed.clone(),
        clustered: v.clustered.clone(),
        prefill_hlo: Box::new(move |s| decode_prefill_hlo(s, D, is_clustered)),
        step_hlo: Box::new(move |s| decode_step_hlo(s, D, is_clustered)),
        threads,
    };
    DecodeSession::new(model, BucketLadder::pow2(512))
}

fn time_per<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// KV-cached steps must reproduce the from-scratch prefill before any
/// of them are timed (a broken cache can't post a win).
fn cross_check(v: &Variant, threads: ThreadBudget) -> anyhow::Result<()> {
    let mut session = make_session(v, threads);
    let mut rng = Pcg32::new(31);
    let prompt = rand_tokens(32, &mut rng);
    let y = session.prefill(&prompt)?;
    let mut x = y.slice_rows(31, 32)?;
    let mut prefix = prompt;
    for _ in 0..2 {
        let ys = session.step(&x)?;
        prefix = Tensor::concat_rows(&[&prefix, &x])?;
        let n = prefix.shape()[0];
        let out = session.prefill_resident().run(&[prefix.clone(), scalar(n)])?;
        let y_ref = out[0].slice_rows(n - 1, n)?;
        let (a, b) = (ys.as_f32()?, y_ref.as_f32()?);
        for (ai, bi) in a.iter().zip(&b) {
            assert!(
                (ai - bi).abs() <= 1e-4 * (1.0 + bi.abs()),
                "{}: KV decode diverged from recompute: {ai} vs {bi}",
                v.name
            );
        }
        x = ys;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let threads = ThreadBudget::from_env();
    let mut rng = Pcg32::new(210616007);
    let dense = decode_weights(D, &mut rng);
    let mut variants = vec![Variant {
        name: "f32".to_string(),
        bits: 32,
        fixed: Arc::new(dense.clone()),
        clustered: None,
    }];
    for clusters in [16usize, 64, 256] {
        let ct = Arc::new(decode_clustered(&dense, clusters));
        variants.push(Variant {
            name: format!("c{clusters}"),
            bits: clusters.ilog2(),
            fixed: Arc::new(decode_clustered_inputs(&ct)),
            clustered: Some(ct),
        });
    }

    println!(
        "# autoregressive decode — head dim {D}, {} kernel threads, {STEPS}-step windows\n",
        threads.get()
    );
    println!("| variant | bits | seq | prefill | decode tok/s | recompute tok/s | KV speedup |");
    println!("|---|---|---|---|---|---|---|");

    let mut variants_json = String::new();
    let mut min_kv_speedup_128 = f64::INFINITY;
    for v in &variants {
        cross_check(v, threads)?;
        let mut seqs_json = String::new();
        for &s in &SEQ_LENS {
            let mut session = make_session(v, threads);
            let mut rng = Pcg32::new(9000 + s as u64);
            let prompt = rand_tokens(s, &mut rng);
            session.prefill(&prompt)?; // cold: binds prefill + seed buckets
            let t0 = Instant::now();
            let y = session.prefill(&prompt)?; // warm prefill latency
            let prefill_s = t0.elapsed().as_secs_f64();
            let mut x = y.slice_rows(s - 1, s)?;
            for _ in 0..2 {
                x = session.step(&x)?; // warm the decode bucket
            }
            let step_s = time_per(STEPS, || {
                x = session.step(&x).unwrap();
            });
            assert_eq!(session.len(), s + 2 + STEPS, "every step must land in the cache");

            // No-cache baseline: recompute the full prefix per token,
            // through the already-warm prefill plans.
            let pre = session.prefill_resident();
            let mut prefix = prompt.clone();
            let mut xr = x.clone();
            let warm = Tensor::concat_rows(&[&prefix, &xr])?;
            pre.run(&[warm, scalar(s + 1)])?;
            let recompute_s = time_per(STEPS, || {
                prefix = Tensor::concat_rows(&[&prefix, &xr]).unwrap();
                let n = prefix.shape()[0];
                let out = pre.run(&[prefix.clone(), scalar(n)]).unwrap();
                xr = out[0].slice_rows(n - 1, n).unwrap();
            });

            let kv_speedup = recompute_s / step_s;
            if s >= 128 {
                min_kv_speedup_128 = min_kv_speedup_128.min(kv_speedup);
            }
            println!(
                "| {} | {} | {s} | {} | {:.0} | {:.0} | {kv_speedup:.2}x |",
                v.name,
                v.bits,
                fmt_time(prefill_s),
                1.0 / step_s,
                1.0 / recompute_s
            );
            if !seqs_json.is_empty() {
                seqs_json.push_str(",\n      ");
            }
            seqs_json.push_str(&format!(
                "{{\"seq\": {s}, \"prefill_s\": {prefill_s:.9}, \
                 \"decode_tok_per_s\": {:.3}, \"recompute_tok_per_s\": {:.3}, \
                 \"kv_speedup\": {kv_speedup:.3}, \"step_binds\": {}}}",
                1.0 / step_s,
                1.0 / recompute_s,
                session.rebinds()
            ));
        }
        if !variants_json.is_empty() {
            variants_json.push_str(",\n    ");
        }
        variants_json.push_str(&format!(
            "{{\"name\": \"{}\", \"bits\": {}, \"seqs\": [\n      {seqs_json}\n    ]}}",
            v.name, v.bits
        ));
    }

    // ---- plan-cache hit vs fresh rebind of the same bucket ----
    let fixed = variants[0].fixed.clone();
    let source: ExecSource = Box::new(move |s| {
        Ok(InterpExecutor::load_text(
            &decode_prefill_hlo(s, D, false),
            &format!("bench/dispatch[{s}]"),
        )?
        .with_threads(threads))
    });
    let dyn_res = DynResident::new(
        "bench/dispatch",
        BucketLadder::pow2(512),
        2,
        fixed.clone(),
        None,
        source,
    );
    dyn_res.bind_bucket(128)?; // cold bind, cached from here on
    let hit_s = time_per(1000, || {
        dyn_res.bind_bucket(128).unwrap();
    });
    let exe = InterpExecutor::load_text(&decode_prefill_hlo(128, D, false), "bench/rebind")?
        .with_threads(threads);
    let rebind_s = time_per(5, || {
        exe.resident(2, fixed.clone(), None).unwrap();
    });
    let dispatch_speedup = rebind_s / hit_s;
    println!(
        "\ncache-hit dispatch {} vs rebind {}: {dispatch_speedup:.0}x (target >= 10x: {})",
        fmt_time(hit_s),
        fmt_time(rebind_s),
        if dispatch_speedup >= 10.0 { "MET" } else { "NOT met" }
    );
    println!(
        "KV-cached decode vs recompute at seq >= 128: {min_kv_speedup_128:.2}x minimum \
         (target >= 2x: {})",
        if min_kv_speedup_128 >= 2.0 { "MET" } else { "NOT met" }
    );

    let json = format!(
        "{{\n  \"bench\": \"decode\",\n  \"dim\": {D},\n  \"threads\": {},\n  \
         \"steps_per_window\": {STEPS},\n  \"variants\": [\n    {variants_json}\n  ],\n  \
         \"dispatch\": {{\"cache_hit_s\": {hit_s:.9}, \"rebind_s\": {rebind_s:.9}, \
         \"speedup\": {dispatch_speedup:.3}}},\n  \
         \"kv_speedup_min_at_128\": {min_kv_speedup_128:.3}\n}}\n",
        threads.get()
    );
    let path = std::path::Path::new("BENCH_decode.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    assert!(
        dispatch_speedup >= 10.0,
        "cache-hit dispatch must be >= 10x faster than a rebind (got {dispatch_speedup:.1}x)"
    );
    assert!(
        min_kv_speedup_128 >= 2.0,
        "KV-cached decode must be >= 2x recompute-per-step at seq >= 128 \
         (got {min_kv_speedup_128:.2}x)"
    );
    Ok(())
}
