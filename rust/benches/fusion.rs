//! Operator-fusion bench (ISSUE 5 acceptance): fused vs unfused planned
//! execution on the attention-shaped ViT module from
//! `testing::fixtures::vit_shaped_hlo` — the same graph family
//! `benches/interp_memory.rs` measures.
//!
//! Reports wall time both ways, planned peak bytes both ways, and the
//! per-inference intermediate traffic the fusion pass removes
//! (`fused_bytes_saved`, as a fraction of the unfused write+read
//! traffic `2 * naive_bytes`). Acceptance: both the peak and the
//! traffic drop by >= 25%, with a wall-time win.
//!
//! Also A/Bs the fused path with the kernel dispatch level forced to
//! `scalar` and to the detected vector ISA, isolating the SIMD win on
//! the fused GEMM/softmax/elementwise kernels end to end.

use clusterformer::bench::{fmt_time, BenchConfig, BenchRunner};
use clusterformer::runtime::interp::{
    detected_kernel_isa, force_kernel_isa, InterpExecutor, KernelIsa,
};
use clusterformer::runtime::Executor as _;
use clusterformer::testing::fixtures::{vit_shaped_hlo, vit_shaped_inputs};
use clusterformer::testing::prop::ulp_dist;
use clusterformer::util::rng::Pcg32;

const M: usize = 128;
const D: usize = 16;
const LAYERS: usize = 4;

fn main() -> anyhow::Result<()> {
    let hlo = vit_shaped_hlo(M, D, LAYERS);
    let fused = InterpExecutor::load_text(&hlo, "vit-fused")?.with_fusion(true);
    let unfused = InterpExecutor::load_text(&hlo, "vit-unfused")?.with_fusion(false);

    let mut rng = Pcg32::new(5 * 2106);
    let inputs = vit_shaped_inputs(M, D, LAYERS, &mut rng);

    // Numeric anchor: the fused softmax is the only non-bit-identical
    // lowering; end to end the two paths stay within a few ULP.
    let fo = fused.run(&inputs)?;
    let uo = unfused.run(&inputs)?;
    let (fv, uv) = (fo[0].as_f32()?, uo[0].as_f32()?);
    let max_ulp = fv
        .iter()
        .zip(&uv)
        .map(|(a, b)| ulp_dist(*a, *b))
        .max()
        .unwrap_or(0);

    let fp = fused.memory_plan().expect("fused plan must build");
    let up = unfused.memory_plan().expect("unfused plan must build");
    assert_eq!(up.fused_chains() + up.fused_epilogues() + up.fused_softmax(), 0);

    println!(
        "# Operator fusion — {LAYERS} attention layers of [{M},{D}] \
         ({} chains, {} epilogues, {} softmax)\n",
        fp.fused_chains(),
        fp.fused_epilogues(),
        fp.fused_softmax()
    );
    let mut runner = BenchRunner::new(BenchConfig::default());
    let t_unfused = runner
        .bench("exec/planned-unfused", || unfused.run(&inputs).unwrap())
        .summary
        .mean;
    let t_fused = runner
        .bench("exec/planned-fused", || fused.run(&inputs).unwrap())
        .summary
        .mean;

    // ---- scalar vs SIMD A/B on the fused path ----
    let detected = detected_kernel_isa();
    let mut t_by_isa: Vec<(KernelIsa, f64)> = Vec::new();
    let mut levels = vec![KernelIsa::Scalar];
    if detected != KernelIsa::Scalar {
        levels.push(detected);
    }
    for &isa in &levels {
        force_kernel_isa(Some(isa));
        let t = runner
            .bench(&format!("exec/planned-fused@{}", isa.name()), || {
                fused.run(&inputs).unwrap()
            })
            .summary
            .mean;
        // Softmax is the only reassociated SIMD kernel; end to end each
        // level stays within the same few-ULP envelope as fusion itself.
        let out = fused.run(&inputs).unwrap()[0].as_f32().unwrap();
        let isa_ulp = out.iter().zip(&fv).map(|(a, b)| ulp_dist(*a, *b)).max().unwrap_or(0);
        assert!(isa_ulp <= 4, "{} diverged from auto dispatch: {isa_ulp} ULP", isa.name());
        t_by_isa.push((isa, t));
    }
    force_kernel_isa(None);

    let naive = up.naive_bytes();
    let traffic_drop = fp.fused_bytes_saved() as f64 / (2 * naive).max(1) as f64;
    let peak_drop = 1.0 - fp.peak_bytes() as f64 / up.peak_bytes().max(1) as f64;

    println!("\n| path | mean | peak bytes | slots |");
    println!("|---|---|---|---|");
    println!(
        "| unfused | {} | {} | {} |",
        fmt_time(t_unfused),
        up.peak_bytes(),
        up.slot_count()
    );
    println!(
        "| fused | {} | {} | {} |",
        fmt_time(t_fused),
        fp.peak_bytes(),
        fp.slot_count()
    );
    println!("\nmax end-to-end ULP distance fused vs unfused: {max_ulp}");
    println!(
        "planned peak bytes: {} -> {} ({:.1}% lower; target >= 25%: {})",
        up.peak_bytes(),
        fp.peak_bytes(),
        100.0 * peak_drop,
        if peak_drop >= 0.25 { "PASS" } else { "FAIL" }
    );
    println!(
        "intermediate traffic removed: {} of {} write+read bytes ({:.1}%; target >= 25%: {})",
        fp.fused_bytes_saved(),
        2 * naive,
        100.0 * traffic_drop,
        if traffic_drop >= 0.25 { "PASS" } else { "FAIL" }
    );
    println!(
        "speedup fused vs unfused: {:.2}x ({})",
        t_unfused / t_fused,
        if t_fused < t_unfused { "PASS" } else { "FAIL" }
    );
    if let [(_, t_scalar), (isa, t_simd)] = t_by_isa[..] {
        println!(
            "fused path, {} vs scalar dispatch: {:.2}x",
            isa.name(),
            t_scalar / t_simd
        );
    } else {
        println!("no vector ISA detected: fused-path SIMD A/B skipped");
    }
    runner.finish("operator fusion");
    Ok(())
}
