//! Fig. 2 reproduction: execution-time breakdown of DeiT and ViT by op
//! category.
//!
//! Two complementary estimates, both emitted as paper-style rows:
//! 1. static HLO cost analysis (FLOP shares per category) of the lowered
//!    batch-8 forward pass;
//! 2. measured wall time of the per-op micro modules at model shapes.
//!
//! Paper expectation: MatMul > 50% of execution time; Softmax and
//! normalization next.

use clusterformer::bench::{BenchConfig, BenchRunner};
use clusterformer::hlo::{CostAnalysis, HloModule};
use clusterformer::model::Registry;
use clusterformer::runtime::{default_backend, Backend as _, Executor as _};
use clusterformer::tensor::{Dtype, Tensor};

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    let backend = default_backend()?;

    println!("# Fig. 2 — execution-time breakdown\n");
    for model in ["deit", "vit"] {
        let entry = registry.manifest.model(model)?;
        let module =
            HloModule::parse_file(registry.manifest.path(&entry.hlo_baseline[&8]))?;
        let cost = CostAnalysis::of(&module)?;
        println!("## {model} — static FLOP shares (batch-8 forward)\n");
        println!("| category | share |\n|---|---|");
        for (cat, frac) in cost.flop_breakdown() {
            if frac > 0.0005 {
                println!("| {} | {:.1}% |", cat.name(), frac * 100.0);
            }
        }
        let matmul = cost.flop_breakdown()[0];
        println!(
            "\npaper check: MatMul dominates with {:.1}% (paper: >50%): {}\n",
            matmul.1 * 100.0,
            if matmul.1 > 0.5 { "REPRODUCED" } else { "NOT reproduced" }
        );
    }

    // Measured micro-kernel times at model shapes.
    let mut runner = BenchRunner::new(BenchConfig::default());
    let mut names: Vec<_> = registry.manifest.micro_hlo.keys().cloned().collect();
    names.sort();
    for op in &names {
        let (file, shapes) = &registry.manifest.micro_hlo[op];
        let exe = backend.load_hlo(&registry.manifest.path(file))?;
        let inputs: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::zeros(Dtype::F32, s.clone()))
            .collect();
        runner.bench(&format!("micro/{op}"), || exe.run(&inputs).unwrap());
    }
    // Scale micro measurements by per-layer op multiplicity to estimate a
    // full-pass breakdown (qkv+proj+fc1+fc2 ~ 4 matmuls/block).
    let weight = |op: &str| match op {
        "matmul_qkv" | "matmul_mlp" => 2.0, // two of each shape per block
        _ => 1.0,
    };
    let total: f64 = runner
        .results
        .iter()
        .map(|r| r.summary.mean * weight(&r.name[6..]))
        .sum();
    println!("## measured micro-module shares (model shapes)\n");
    println!("| op | mean | est. share |\n|---|---|---|");
    for r in &runner.results {
        let share = r.summary.mean * weight(&r.name[6..]) / total;
        println!(
            "| {} | {} | {:.1}% |",
            r.name,
            clusterformer::bench::fmt_time(r.summary.mean),
            share * 100.0
        );
    }
    runner.finish("fig2 time breakdown micro");
    Ok(())
}
