//! §V-C reproduction: model memory usage before/after clustering.
//!
//! Paper claims: 32-bit parameters -> 8-bit indices = 4x reduction in
//! model size and bandwidth; the table of centroids is tiny (256 B for
//! 64 clusters).

use clusterformer::model::Registry;

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    println!("# §V-C — memory usage (model size) before/after clustering\n");
    for model in ["vit", "deit"] {
        let entry = registry.manifest.model(model)?;
        let base = entry.total_param_bytes() as f64;
        println!(
            "## {model} — baseline {:.2} MB FP32 ({} parameter tensors)\n",
            base / 1e6,
            entry.params.len()
        );
        println!("| scheme | clusters | model MB | compression | table bytes |");
        println!("|---|---|---|---|---|");
        let mut keys: Vec<_> = entry.clustered_files.keys().cloned().collect();
        keys.sort_by_key(|k| {
            let (s, c) = k.rsplit_once('_').unwrap();
            (s.to_string(), c.parse::<usize>().unwrap_or(0))
        });
        for k in &keys {
            let bytes = entry.variant_bytes(k)? as f64;
            println!(
                "| {} | {} | {:.2} | {:.2}x | {} |",
                k.rsplit_once('_').unwrap().0,
                k.rsplit_once('_').unwrap().1,
                bytes / 1e6,
                base / bytes,
                entry.table_bytes[k]
            );
        }
        // paper checks
        let c64 = entry.variant_bytes("entire_64")? as f64;
        let ratio = base / c64;
        println!(
            "\npaper check: ~4x compression at 64 clusters (measured {ratio:.2}x): {}",
            if ratio > 3.5 { "REPRODUCED" } else { "NOT reproduced" }
        );
        println!(
            "paper check: 256 B table of centroids at 64 clusters (entire): {} B — {}\n",
            entry.table_bytes["entire_64"],
            if entry.table_bytes["entire_64"] == 256 { "REPRODUCED" } else { "NOT reproduced" }
        );
    }
    Ok(())
}
