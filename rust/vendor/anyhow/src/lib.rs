//! Vendored, std-only subset of the `anyhow` error-handling API.
//!
//! The runtime crate must build on machines with no network access and no
//! pre-populated cargo registry (the resource-constrained CI boxes the
//! paper targets), so the small slice of `anyhow` this codebase uses is
//! reimplemented here as a path dependency:
//!
//! * [`Error`] / [`Result`] — a context-chain error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — ad-hoc error construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! `Display` prints the outermost message; `{:#}` prints the whole chain
//! separated by `": "`; `Debug` prints the message plus a `Caused by:`
//! list, matching what `unwrap`/`expect` show with the real crate.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialized to [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error: the outermost context first, innermost cause
/// last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("reading config")
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let err = fails_io().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        assert_eq!(format!("{err:#}"), "reading config: gone");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("gone"));
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("got {n} and {}", 4);
        assert_eq!(e.to_string(), "got 3 and 4");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn option_context() {
        let none: Option<usize> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(1).with_context(|| "unused").unwrap(), 1);
    }

    #[test]
    fn with_context_wraps_inner_error() {
        let err: Error = fails_io().with_context(|| format!("pass {}", 2)).unwrap_err();
        let chain: Vec<_> = err.chain().collect();
        assert_eq!(chain, vec!["pass 2", "reading config", "gone"]);
        assert_eq!(err.root_cause(), "gone");
    }
}
