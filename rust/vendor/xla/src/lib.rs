//! Offline API stub of the `xla` PJRT bindings (crates.io `xla = "0.1.6"`).
//!
//! The build environments this repo targets (edge CI boxes, air-gapped
//! containers) have neither network access nor a native XLA install, so
//! the `pjrt` cargo feature resolves to this stub by default: it exposes
//! the exact API surface `runtime::pjrt` uses, compiles with zero native
//! dependencies, and fails **at runtime** with an instructive error.
//!
//! To execute through a real PJRT client, point the `xla` dependency in
//! `rust/Cargo.toml` at the real crate (or `[patch]` it) on a machine
//! with an XLA installation — `runtime::pjrt` compiles unchanged against
//! either.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `Send + Sync + 'static` bound.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build uses the offline xla API stub; point the `xla` \
         dependency at the real crate (see rust/README.md) or run with the \
         default interpreter backend (--backend interp)"
    )))
}

/// Element types of the PJRT C API (discriminants irrelevant to the stub).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    C64,
    C128,
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        stub_err("creating PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("compiling")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        stub_err("uploading buffer")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        stub_err("parsing HLO text")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("executing")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("executing")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("downloading buffer")
    }
}

pub struct Shape;

impl Shape {
    pub fn is_tuple(&self) -> bool {
        false
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        Vec::new()
    }

    pub fn ty(&self) -> ElementType {
        ElementType::F32
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub_err("creating literal")
    }

    pub fn shape(&self) -> Result<Shape> {
        stub_err("reading literal shape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub_err("reading literal array shape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub_err("decomposing tuple literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err("reading literal data")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_instructive() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        let msg = err.to_string();
        assert!(msg.contains("stub"));
        assert!(msg.contains("interp"));
    }
}
