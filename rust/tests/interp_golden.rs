//! Integration: the pure-Rust HLO interpreter against a hand-written
//! 2-layer MLP module with logits computed independently in plain Rust.
//! Runs with **no artifacts** — this is the numeric anchor for the
//! default backend on a fresh clone.

use clusterformer::runtime::{backend, Backend as _, BackendKind, Executor as _, ResidentExecutor as _};
use clusterformer::tensor::Tensor;

/// `logits = relu(x @ w1 + b1) @ w2 + b2`, as jax would lower it
/// (explicit broadcasts, ROOT tuple).
const MLP_HLO: &str = r#"HloModule mlp_golden, entry_computation_layout={(f32[2,4]{1,0}, f32[4,8]{1,0}, f32[8]{0}, f32[8,3]{1,0}, f32[3]{0})->(f32[2,3]{1,0})}

ENTRY %main.20 (x.1: f32[2,4], w1.2: f32[4,8], b1.3: f32[8], w2.4: f32[8,3], b2.5: f32[3]) -> (f32[2,3]) {
  %x.1 = f32[2,4]{1,0} parameter(0)
  %w1.2 = f32[4,8]{1,0} parameter(1)
  %b1.3 = f32[8]{0} parameter(2)
  %w2.4 = f32[8,3]{1,0} parameter(3)
  %b2.5 = f32[3]{0} parameter(4)
  %dot.6 = f32[2,8]{1,0} dot(%x.1, %w1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %broadcast.7 = f32[2,8]{1,0} broadcast(%b1.3), dimensions={1}
  %add.8 = f32[2,8]{1,0} add(%dot.6, %broadcast.7)
  %constant.9 = f32[] constant(0)
  %broadcast.10 = f32[2,8]{1,0} broadcast(%constant.9), dimensions={}
  %maximum.11 = f32[2,8]{1,0} maximum(%add.8, %broadcast.10)
  %dot.12 = f32[2,3]{1,0} dot(%maximum.11, %w2.4), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %broadcast.13 = f32[2,3]{1,0} broadcast(%b2.5), dimensions={1}
  %add.14 = f32[2,3]{1,0} add(%dot.12, %broadcast.13)
  ROOT %tuple.15 = (f32[2,3]{1,0}) tuple(%add.14)
}
"#;

/// Deterministic but non-trivial weights (signed, non-integer).
fn weights() -> (Tensor, Tensor, Tensor, Tensor) {
    let w1: Vec<f32> = (0..4 * 8)
        .map(|i| ((i as f32) * 0.37).sin() * 0.5)
        .collect();
    let b1: Vec<f32> = (0..8).map(|i| 0.1 * (i as f32) - 0.3).collect();
    let w2: Vec<f32> = (0..8 * 3)
        .map(|i| ((i as f32) * 0.61).cos() * 0.4)
        .collect();
    let b2: Vec<f32> = vec![0.05, -0.2, 0.15];
    (
        Tensor::from_f32(vec![4, 8], &w1).unwrap(),
        Tensor::from_f32(vec![8], &b1).unwrap(),
        Tensor::from_f32(vec![8, 3], &w2).unwrap(),
        Tensor::from_f32(vec![3], &b2).unwrap(),
    )
}

fn images() -> Tensor {
    let x: Vec<f32> = (0..2 * 4).map(|i| ((i as f32) * 0.83).sin()).collect();
    Tensor::from_f32(vec![2, 4], &x).unwrap()
}

/// Reference logits via plain nested loops (no interpreter code shared).
fn reference_logits(x: &Tensor, w1: &Tensor, b1: &Tensor, w2: &Tensor, b2: &Tensor) -> Vec<f32> {
    let (xv, w1v, b1v) = (x.as_f32().unwrap(), w1.as_f32().unwrap(), b1.as_f32().unwrap());
    let (w2v, b2v) = (w2.as_f32().unwrap(), b2.as_f32().unwrap());
    let mut hidden = vec![0.0f32; 2 * 8];
    for r in 0..2 {
        for c in 0..8 {
            let mut acc = b1v[c];
            for k in 0..4 {
                acc += xv[r * 4 + k] * w1v[k * 8 + c];
            }
            hidden[r * 8 + c] = acc.max(0.0);
        }
    }
    let mut logits = vec![0.0f32; 2 * 3];
    for r in 0..2 {
        for c in 0..3 {
            let mut acc = b2v[c];
            for k in 0..8 {
                acc += hidden[r * 8 + k] * w2v[k * 3 + c];
            }
            logits[r * 3 + c] = acc;
        }
    }
    logits
}

fn write_module() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "clusterformer-golden-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mlp_golden.hlo.txt");
    std::fs::write(&path, MLP_HLO).unwrap();
    path
}

#[test]
fn mlp_golden_logits_match_reference() {
    let path = write_module();
    let backend = backend(BackendKind::Interp).unwrap();
    let exe = backend.load_hlo(&path).unwrap();

    let x = images();
    let (w1, b1, w2, b2) = weights();
    let expected = reference_logits(&x, &w1, &b1, &w2, &b2);

    // Full-input path.
    let out = exe
        .run(&[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone()])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[2, 3]);
    let got = out[0].as_f32().unwrap();
    for (g, e) in got.iter().zip(&expected) {
        assert!(
            (g - e).abs() <= 1e-5,
            "full-input path diverges: got {g}, expected {e}"
        );
    }

    // Weight-resident path must agree exactly with the same module.
    let resident = exe
        .with_resident(1, std::sync::Arc::new(vec![w1, b1, w2, b2]))
        .unwrap();
    resident.warmup().unwrap();
    let out2 = resident.run(std::slice::from_ref(&x)).unwrap();
    assert_eq!(out2[0].shape(), &[2, 3]);
    let got2 = out2[0].as_f32().unwrap();
    for (g, e) in got2.iter().zip(&expected) {
        assert!(
            (g - e).abs() <= 1e-5,
            "resident path diverges: got {g}, expected {e}"
        );
    }
}

#[test]
fn mlp_golden_rejects_bad_inputs() {
    let path = write_module();
    let backend = backend(BackendKind::Interp).unwrap();
    let exe = backend.load_hlo(&path).unwrap();
    let (w1, b1, w2, b2) = weights();
    // missing inputs
    assert!(exe.run(&[images()]).is_err());
    // shape mismatch on the image input
    let bad = Tensor::from_f32(vec![2, 5], &[0.0; 10]).unwrap();
    assert!(exe.run(&[bad, w1, b1, w2, b2]).is_err());
}
