//! Concurrent-serving stress (ISSUE 4): a `Server` with TWO variants of
//! a tiny synthetic model — so two workers share the persistent kernel
//! pool under divided thread budgets — hammered by many client threads
//! through the `Router`. Asserts every request gets exactly one reply,
//! metrics totals match what was sent, answers are numerically right,
//! and shutdown flushes cleanly.
//!
//! Needs **no prebuilt artifacts**: `testing::SyntheticServing` writes a
//! complete artifacts directory (manifest + weights tpak + clustered
//! tpak + baseline/clustered HLO at batch 1 and 4) into a temp dir.
//!
//! CI also runs this test once with `CLUSTERFORMER_FAULTS` slowing the
//! `tiny/baseline` label: every assertion here must hold under injected
//! slowness too (only wall time changes).

use std::time::Duration;

use clusterformer::coordinator::{BatchPolicy, BatcherConfig, Server, ServerConfig};
use clusterformer::model::VariantKey;
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::testing::synthetic::{SyntheticServing, CLASSES, CLUSTERS};

fn start_server(dir: &std::path::Path, total_threads: usize) -> Server {
    // total_threads lanes divided across the 2 variant workers by
    // Server::start.
    Server::start(ServerConfig {
        artifacts_dir: dir.to_path_buf(),
        targets: vec![
            ("tiny".to_string(), VariantKey::Baseline),
            ("tiny".to_string(), SyntheticServing::clustered_key()),
        ],
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        // e.g. 4 => 2 workers x 2 lanes on one shared process pool.
        threads: ThreadBudget::new(total_threads),
        resilience: Default::default(),
    })
    .expect("synthetic server must start")
}

#[test]
fn two_variant_server_survives_concurrent_clients() {
    let synth = SyntheticServing::build("tiny");
    let server = start_server(&synth.dir, 4);
    let targets = [synth.baseline_target(), synth.clustered_target()];
    assert_eq!(targets[1], format!("tiny/perlayer_{CLUSTERS}"));

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 30;
    let router = server.router.clone();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let router = router.clone();
            let targets = targets.clone();
            std::thread::spawn(move || {
                let mut pending = Vec::with_capacity(PER_CLIENT);
                for i in 0..PER_CLIENT {
                    let target = &targets[(c + i) % 2];
                    let img = SyntheticServing::image((c * PER_CLIENT + i) as u64 + 1);
                    let (id, rx) = router.submit(target, img).unwrap();
                    pending.push((id, target.clone(), rx));
                }
                let mut got = Vec::with_capacity(PER_CLIENT);
                for (id, target, rx) in pending {
                    let resp = rx
                        .recv_timeout(Duration::from_secs(60))
                        .expect("every request must get a reply");
                    assert_eq!(resp.id, id, "reply id must match request id");
                    assert_eq!(resp.logits.len(), CLASSES);
                    assert!(resp.served_by.starts_with(target.as_str()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
                    // Exactly ONE reply: the worker dropped its sender, so
                    // a second receive must report disconnection, never a
                    // duplicate response.
                    assert!(
                        rx.recv_timeout(Duration::from_millis(50)).is_err(),
                        "request {id} answered twice"
                    );
                    got.push(resp);
                }
                got.len()
            })
        })
        .collect();
    let answered: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let sent = CLIENTS * PER_CLIENT;
    assert_eq!(answered, sent);

    // Metrics totals must account for every request, with zero
    // rejections and both variants actually serving.
    let snap = server.snapshot();
    assert_eq!(snap.total_requests(), sent as u64);
    for target in &targets {
        let v = &snap.per_variant[target.as_str()];
        assert_eq!(v.requests, (sent / 2) as u64, "{target}");
        assert!(v.batches >= 1);
        assert_eq!(v.rejected, 0, "{target}");
        assert!(v.mean_batch_size() >= 1.0);
    }

    // Numeric spot-check under concurrency: both variants must produce
    // the reference answer (clustered against the dequantized weights,
    // within LUT reassociation error).
    let x = SyntheticServing::image(777);
    let (_, rx) = router.submit(&targets[0], x.clone()).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let want = synth.reference_logits(&x);
    for (g, e) in resp.logits.iter().zip(&want) {
        assert!((g - e).abs() <= 1e-4, "baseline logits diverged: {g} vs {e}");
    }
    let (_, rx) = router.submit(&targets[1], x.clone()).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let want_q = synth.reference_logits_clustered(&x);
    for (g, e) in resp.logits.iter().zip(&want_q) {
        assert!(
            (g - e).abs() <= 1e-3 * (1.0 + e.abs()),
            "clustered logits diverged: {g} vs {e}"
        );
    }

    // Shutdown must flush: requests submitted just before the shutdown
    // message still get answered (channel order guarantees they precede
    // it).
    let mut last = Vec::new();
    for i in 0..5 {
        for target in &targets {
            last.push(router.submit(target, SyntheticServing::image(9000 + i)).unwrap().1);
        }
    }
    server.shutdown();
    for rx in last {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("shutdown must flush in-flight requests");
        assert_eq!(resp.logits.len(), CLASSES);
    }

    synth.cleanup();
}
