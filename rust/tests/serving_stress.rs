//! Concurrent-serving stress (ISSUE 4): a `Server` with TWO variants of
//! a tiny synthetic model — so two workers share the persistent kernel
//! pool under divided thread budgets — hammered by many client threads
//! through the `Router`. Asserts every request gets exactly one reply,
//! metrics totals match what was sent, answers are numerically right,
//! and shutdown flushes cleanly.
//!
//! Unlike `coordinator_e2e.rs` this needs **no prebuilt artifacts**: the
//! test writes its own artifacts directory (manifest + weights tpak +
//! clustered tpak + baseline/clustered HLO at batch 1 and 4) into a temp
//! dir, with the clustered HLO using the exact `u8 indices -> convert ->
//! gather(codebook row) -> dot` lowering the LUT planner recognizes.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use clusterformer::clustering::{ClusterScheme, ClusteredTensors, Quantizer};
use clusterformer::coordinator::{BatchPolicy, BatcherConfig, Server, ServerConfig};
use clusterformer::model::VariantKey;
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::tensor::{io, io::TensorPack, Tensor};
use clusterformer::util::rng::Pcg32;

/// Tiny classifier over [2,2,3] "images": logits = reshape(x) @ w + b,
/// with w [12, 4] (clustered in the second variant) and bias b [4].
const K: usize = 12;
const CLASSES: usize = 4;
const CLUSTERS: usize = 8;

fn baseline_hlo(batch: usize) -> String {
    format!(
        "HloModule tiny_baseline_b{batch}\n\
         ENTRY %main (x: f32[{batch},2,2,3], w: f32[{K},{CLASSES}], b0: f32[{CLASSES}]) -> (f32[{batch},{CLASSES}]) {{\n  \
         %x = f32[{batch},2,2,3]{{3,2,1,0}} parameter(0)\n  \
         %w = f32[{K},{CLASSES}]{{1,0}} parameter(1)\n  \
         %b0 = f32[{CLASSES}]{{0}} parameter(2)\n  \
         %xr = f32[{batch},{K}]{{1,0}} reshape(%x)\n  \
         %d = f32[{batch},{CLASSES}]{{1,0}} dot(%xr, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n  \
         %bb = f32[{batch},{CLASSES}]{{1,0}} broadcast(%b0), dimensions={{1}}\n  \
         %o = f32[{batch},{CLASSES}]{{1,0}} add(%d, %bb)\n  \
         ROOT %t = (f32[{batch},{CLASSES}]{{1,0}}) tuple(%o)\n}}\n"
    )
}

fn clustered_hlo(batch: usize) -> String {
    // Input order is the clustered-variant contract: (images, codebooks,
    // *leaves) with the clustered w as u8 indices and the bias as f32.
    format!(
        "HloModule tiny_clustered_b{batch}\n\
         ENTRY %main (x: f32[{batch},2,2,3], cbs: f32[1,256], idxw: u8[{K},{CLASSES}], b0: f32[{CLASSES}]) -> (f32[{batch},{CLASSES}]) {{\n  \
         %x = f32[{batch},2,2,3]{{3,2,1,0}} parameter(0)\n  \
         %cbs = f32[1,256]{{1,0}} parameter(1)\n  \
         %idxw = u8[{K},{CLASSES}]{{1,0}} parameter(2)\n  \
         %b0 = f32[{CLASSES}]{{0}} parameter(3)\n  \
         %xr = f32[{batch},{K}]{{1,0}} reshape(%x)\n  \
         %sl = f32[1,256]{{1,0}} slice(%cbs), slice={{[0:1], [0:256]}}\n  \
         %row = f32[256]{{0}} reshape(%sl)\n  \
         %cvt = s32[{K},{CLASSES}]{{1,0}} convert(%idxw)\n  \
         %w = f32[{K},{CLASSES}]{{1,0}} gather(%row, %cvt), offset_dims={{}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim=2, slice_sizes={{1}}\n  \
         %d = f32[{batch},{CLASSES}]{{1,0}} dot(%xr, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n  \
         %bb = f32[{batch},{CLASSES}]{{1,0}} broadcast(%b0), dimensions={{1}}\n  \
         %o = f32[{batch},{CLASSES}]{{1,0}} add(%d, %bb)\n  \
         ROOT %t = (f32[{batch},{CLASSES}]{{1,0}}) tuple(%o)\n}}\n"
    )
}

fn manifest_json() -> String {
    format!(
        r#"{{
  "version": 1, "quick": true,
  "data": {{"val": "val.tpak", "n_val": 0, "n_classes": {CLASSES}, "img_size": 2}},
  "cluster_sweep": [{CLUSTERS}], "schemes": ["perlayer"],
  "codebook_pad": 256, "batch_sizes": [1, 4], "golden_n": 0,
  "models": {{
    "tiny": {{
      "config": {{"name": "tiny", "img_size": 2, "patch": 1, "dim": 4,
                 "depth": 1, "heads": 1, "mlp_ratio": 1, "n_classes": {CLASSES},
                 "distilled": false}},
      "params": [
        {{"name": "w", "shape": [{K}, {CLASSES}], "clustered": true}},
        {{"name": "b", "shape": [{CLASSES}], "clustered": false}}
      ],
      "weights": "tiny_weights.tpak",
      "clustered": {{"perlayer_{CLUSTERS}": {{"file": "tiny_clustered.tpak", "table_bytes": {table}}}}},
      "hlo": {{"baseline": {{"1": "tiny_b1.hlo.txt", "4": "tiny_b4.hlo.txt"}},
              "clustered": {{"1": "tiny_c1.hlo.txt", "4": "tiny_c4.hlo.txt"}}}},
      "goldens": "tiny_goldens.tpak",
      "baseline_top1": 0.0, "baseline_top5": 0.0
    }}
  }}
}}"#,
        table = CLUSTERS * 4
    )
}

/// Write the synthetic artifacts directory; returns (dir, w, b, ct) so
/// tests can compute reference answers.
fn build_artifacts(tag: &str) -> (PathBuf, Vec<f32>, Vec<f32>, ClusteredTensors) {
    let dir = std::env::temp_dir().join(format!(
        "clusterformer-stress-{tag}-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();

    let mut rng = Pcg32::new(20210616);
    let w: Vec<f32> = (0..K * CLASSES).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..CLASSES).map(|_| rng.normal() as f32 * 0.1).collect();
    let wt = Tensor::from_f32(vec![K, CLASSES], &w).unwrap();
    let bt = Tensor::from_f32(vec![CLASSES], &b).unwrap();

    let mut weights = TensorPack::new();
    weights.insert("w", wt.clone());
    weights.insert("b", bt);
    io::write_tpak(dir.join("tiny_weights.tpak"), &weights).unwrap();

    let names = vec!["w".to_string()];
    let mut tensors = HashMap::new();
    tensors.insert("w".to_string(), wt);
    let ct = Quantizer::new(CLUSTERS, ClusterScheme::PerLayer)
        .run(&names, &tensors)
        .unwrap();
    io::write_tpak(dir.join("tiny_clustered.tpak"), &ct.to_pack()).unwrap();

    std::fs::write(dir.join("tiny_b1.hlo.txt"), baseline_hlo(1)).unwrap();
    std::fs::write(dir.join("tiny_b4.hlo.txt"), baseline_hlo(4)).unwrap();
    std::fs::write(dir.join("tiny_c1.hlo.txt"), clustered_hlo(1)).unwrap();
    std::fs::write(dir.join("tiny_c4.hlo.txt"), clustered_hlo(4)).unwrap();
    std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
    (dir, w, b, ct)
}

fn image(seed: u64) -> Tensor {
    let mut rng = Pcg32::new(seed);
    let vals: Vec<f32> = (0..K).map(|_| rng.normal() as f32).collect();
    Tensor::from_f32(vec![2, 2, 3], &vals).unwrap()
}

/// Reference logits: flatten(x) @ weights + b (weights column-major over
/// [K, CLASSES] row-major layout).
fn reference_logits(x: &Tensor, w: &[f32], b: &[f32]) -> Vec<f32> {
    let xv = x.as_f32().unwrap();
    (0..CLASSES)
        .map(|c| {
            let mut acc = b[c];
            for i in 0..K {
                acc += xv[i] * w[i * CLASSES + c];
            }
            acc
        })
        .collect()
}

fn start_server(dir: &std::path::Path, total_threads: usize) -> Server {
    // total_threads lanes divided across the 2 variant workers by
    // Server::start.
    Server::start(ServerConfig {
        artifacts_dir: dir.to_path_buf(),
        targets: vec![
            ("tiny".to_string(), VariantKey::Baseline),
            (
                "tiny".to_string(),
                VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: CLUSTERS },
            ),
        ],
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        // e.g. 4 => 2 workers x 2 lanes on one shared process pool.
        threads: ThreadBudget::new(total_threads),
    })
    .expect("synthetic server must start")
}

#[test]
fn two_variant_server_survives_concurrent_clients() {
    let (dir, w, b, ct) = build_artifacts("stress");
    let server = start_server(&dir, 4);
    let targets = ["tiny/baseline".to_string(), format!("tiny/perlayer_{CLUSTERS}")];

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 30;
    let router = server.router.clone();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let router = router.clone();
            let targets = targets.clone();
            std::thread::spawn(move || {
                let mut pending = Vec::with_capacity(PER_CLIENT);
                for i in 0..PER_CLIENT {
                    let target = &targets[(c + i) % 2];
                    let img = image((c * PER_CLIENT + i) as u64 + 1);
                    let (id, rx) = router.submit(target, img).unwrap();
                    pending.push((id, target.clone(), rx));
                }
                let mut got = Vec::with_capacity(PER_CLIENT);
                for (id, target, rx) in pending {
                    let resp = rx
                        .recv_timeout(Duration::from_secs(60))
                        .expect("every request must get a reply");
                    assert_eq!(resp.id, id, "reply id must match request id");
                    assert_eq!(resp.logits.len(), CLASSES);
                    assert!(resp.served_by.starts_with(target.as_str()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
                    // Exactly ONE reply: the worker dropped its sender, so
                    // a second receive must report disconnection, never a
                    // duplicate response.
                    assert!(
                        rx.recv_timeout(Duration::from_millis(50)).is_err(),
                        "request {id} answered twice"
                    );
                    got.push(resp);
                }
                got.len()
            })
        })
        .collect();
    let answered: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let sent = CLIENTS * PER_CLIENT;
    assert_eq!(answered, sent);

    // Metrics totals must account for every request, with zero
    // rejections and both variants actually serving.
    let snap = server.snapshot();
    assert_eq!(snap.total_requests(), sent as u64);
    for target in &targets {
        let v = &snap.per_variant[target.as_str()];
        assert_eq!(v.requests, (sent / 2) as u64, "{target}");
        assert!(v.batches >= 1);
        assert_eq!(v.rejected, 0, "{target}");
        assert!(v.mean_batch_size() >= 1.0);
    }

    // Numeric spot-check under concurrency: both variants must produce
    // the reference answer (clustered against the dequantized weights,
    // within LUT reassociation error).
    let x = image(777);
    let wq: Vec<f32> = {
        let idx = ct.indices["w"].as_u8().unwrap();
        let cb = ct.codebooks.as_f32().unwrap();
        idx.iter().map(|&i| cb[i as usize]).collect()
    };
    let (_, rx) = router.submit(&targets[0], x.clone()).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let want = reference_logits(&x, &w, &b);
    for (g, e) in resp.logits.iter().zip(&want) {
        assert!((g - e).abs() <= 1e-4, "baseline logits diverged: {g} vs {e}");
    }
    let (_, rx) = router.submit(&targets[1], x.clone()).unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let want_q = reference_logits(&x, &wq, &b);
    for (g, e) in resp.logits.iter().zip(&want_q) {
        assert!(
            (g - e).abs() <= 1e-3 * (1.0 + e.abs()),
            "clustered logits diverged: {g} vs {e}"
        );
    }

    // Shutdown must flush: requests submitted just before the shutdown
    // message still get answered (channel order guarantees they precede
    // it).
    let mut last = Vec::new();
    for i in 0..5 {
        for target in &targets {
            last.push(router.submit(target, image(9000 + i)).unwrap().1);
        }
    }
    server.shutdown();
    for rx in last {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("shutdown must flush in-flight requests");
        assert_eq!(resp.logits.len(), CLASSES);
    }

    let _ = std::fs::remove_dir_all(&dir);
}
