//! Integration: the artifact contract between `python/compile/aot.py` and
//! the Rust side — manifest consistency, tpak layouts, HLO parameter
//! signatures matching the manifest order.

mod common;

use clusterformer::hlo::HloModule;
use clusterformer::model::Registry;
use clusterformer::tensor::Dtype;

#[test]
fn manifest_and_packs_are_consistent() {
    if !common::artifacts_available("manifest_and_packs_are_consistent") {
        return;
    }
    let mut registry = Registry::load("artifacts").expect("run `make artifacts`");
    let models = registry.model_names();
    assert_eq!(models, vec!["deit", "vit"]);
    for model in models {
        let entry = registry.manifest.model(&model).unwrap().clone();
        // every manifest param exists in the weights pack at its shape
        let weights = registry.weights(&model).unwrap();
        assert_eq!(weights.len(), entry.params.len());
        for spec in &entry.params {
            assert_eq!(weights[&spec.name].shape(), spec.shape.as_slice());
            assert_eq!(weights[&spec.name].dtype(), Dtype::F32);
        }
        // deit has the distillation extras, vit does not
        let has_dist = entry.params.iter().any(|p| p.name == "dist_token");
        assert_eq!(has_dist, entry.config.distilled);
    }
}

#[test]
fn hlo_signatures_match_manifest_order() {
    if !common::artifacts_available("hlo_signatures_match_manifest_order") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    for model in ["vit", "deit"] {
        let entry = registry.manifest.model(model).unwrap();
        for (&batch, file) in &entry.hlo_baseline {
            let module = HloModule::parse_file(registry.manifest.path(file)).unwrap();
            let params = module.parameters().unwrap();
            // (images, *manifest params)
            assert_eq!(params.len(), 1 + entry.params.len(), "{file}");
            assert_eq!(
                params[0].1.dims,
                vec![batch, entry.config.img_size, entry.config.img_size, 3],
                "{file}: images shape"
            );
            for (spec, (_, shape)) in entry.params.iter().zip(&params[1..]) {
                assert_eq!(
                    shape.dims, spec.shape,
                    "{file}: {} shape mismatch",
                    spec.name
                );
                assert_eq!(shape.dtype, "f32", "{file}: {}", spec.name);
            }
        }
        for (&batch, file) in &entry.hlo_clustered {
            let module = HloModule::parse_file(registry.manifest.path(file)).unwrap();
            let params = module.parameters().unwrap();
            // (images, codebooks, *leaves)
            assert_eq!(params.len(), 2 + entry.params.len(), "{file}");
            assert_eq!(params[0].1.dims[0], batch, "{file}");
            assert_eq!(
                params[1].1.dims,
                vec![
                    entry.clustered_names().len(),
                    registry.manifest.codebook_pad
                ],
                "{file}: codebook stack"
            );
            for (spec, (_, shape)) in entry.params.iter().zip(&params[2..]) {
                assert_eq!(shape.dims, spec.shape, "{file}: {}", spec.name);
                let want = if spec.clustered { "u8" } else { "f32" };
                assert_eq!(shape.dtype, want, "{file}: {} dtype", spec.name);
            }
        }
    }
}

#[test]
fn val_set_matches_manifest() {
    if !common::artifacts_available("val_set_matches_manifest") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    let (images, labels) = registry.val_set().unwrap();
    assert_eq!(images.shape()[0], registry.manifest.n_val);
    assert_eq!(labels.len(), registry.manifest.n_val);
    assert_eq!(images.shape()[1], registry.manifest.img_size);
    let max = labels.iter().copied().max().unwrap();
    assert!((max as usize) < registry.manifest.n_classes);
    // images are normalized to [0, 1]
    let v = images.slice_rows(0, 4).unwrap().as_f32().unwrap();
    assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
}

#[test]
fn clustered_packs_complete_for_whole_sweep() {
    if !common::artifacts_available("clustered_packs_complete_for_whole_sweep") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    for model in ["vit", "deit"] {
        let entry = registry.manifest.model(model).unwrap();
        for scheme in &registry.manifest.schemes {
            for &c in &registry.manifest.cluster_sweep {
                let key = format!("{scheme}_{c}");
                assert!(
                    entry.clustered_files.contains_key(&key),
                    "{model}: missing clustered variant {key}"
                );
                let scheme = clusterformer::clustering::ClusterScheme::parse(scheme).unwrap();
                let ct = registry.clustered(model, scheme, c).unwrap();
                assert_eq!(ct.names, entry.clustered_names());
            }
        }
    }
}

#[test]
fn every_hlo_artifact_parses_with_sane_costs() {
    if !common::artifacts_available("every_hlo_artifact_parses_with_sane_costs") {
        return;
    }
    // Robustness sweep of the HLO parser + cost analysis over every
    // artifact the AOT pipeline produced.
    use clusterformer::hlo::{CostAnalysis, OpCategory};
    let mut checked = 0;
    for file in std::fs::read_dir("artifacts").unwrap() {
        let path = file.unwrap().path();
        if path.extension().is_none_or(|e| e != "txt") {
            continue;
        }
        let module = HloModule::parse_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let cost = CostAnalysis::of(&module)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(cost.parameter_bytes > 0, "{}", path.display());
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if name.contains("baseline") || name.contains("clustered") {
            // full forward passes must show matmul work
            let mm = cost.flops.get(&OpCategory::MatMul).copied().unwrap_or(0.0);
            assert!(mm > 0.0, "{name}: no matmul flops found");
        }
        checked += 1;
    }
    assert!(checked >= 17, "expected all HLO artifacts, checked {checked}");
}

#[test]
fn clustered_stream_is_about_4x_smaller() {
    if !common::artifacts_available("clustered_stream_is_about_4x_smaller") {
        return;
    }
    // The headline §V-C claim as a regression test.
    let mut registry = Registry::load("artifacts").unwrap();
    for model in ["vit", "deit"] {
        use clusterformer::model::VariantKey;
        let base = registry
            .variant(model, VariantKey::Baseline)
            .unwrap()
            .weight_stream_bytes as f64;
        let clus = registry
            .variant(
                model,
                VariantKey::Clustered {
                    scheme: clusterformer::clustering::ClusterScheme::Entire,
                    clusters: 64,
                },
            )
            .unwrap()
            .weight_stream_bytes as f64;
        let ratio = base / clus;
        assert!(
            (3.5..=4.0).contains(&ratio),
            "{model}: compression ratio {ratio}"
        );
    }
}

#[test]
fn registry_error_paths() {
    if !common::artifacts_available("registry_error_paths") {
        return;
    }
    use clusterformer::model::VariantKey;
    let mut registry = Registry::load("artifacts").unwrap();
    assert!(registry.manifest.model("nope").is_err());
    assert!(registry
        .variant(
            "vit",
            VariantKey::Clustered {
                scheme: clusterformer::clustering::ClusterScheme::Entire,
                clusters: 7, // not in the sweep
            },
        )
        .is_err());
    assert!(Registry::load("/nonexistent-dir").is_err());
}
