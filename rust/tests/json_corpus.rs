//! Malformed-input corpus for the zero-copy JSON lexer: truncated
//! documents, nesting bombs, invalid UTF-8, non-finite numbers, bad
//! escapes, and trailing garbage. Every entry must produce a **typed,
//! position-carrying [`JsonError`]** — never a panic, never a hang,
//! never `inf`/`NaN` smuggled into the pipeline.
//!
//! [`JsonError`]: clusterformer::util::json::JsonError

use clusterformer::util::json::{
    parse, parse_bytes, Json, JsonError, JsonErrorKind, Lexer, MAX_DEPTH,
};

fn kind_of(doc: &[u8]) -> JsonError {
    parse_bytes(doc).expect_err("corpus entry must be rejected")
}

#[test]
fn truncated_documents_are_typed_errors() {
    // (doc, expected kind) — every truncation point in the grammar.
    let corpus: &[(&[u8], JsonErrorKind)] = &[
        (b"", JsonErrorKind::Eof),
        (b"   ", JsonErrorKind::Eof),
        (b"\"abc", JsonErrorKind::Eof),
        (b"{", JsonErrorKind::Eof),
        (b"{\"a\"", JsonErrorKind::Eof),
        (b"{\"a\":", JsonErrorKind::Eof),
        (b"{\"a\":1", JsonErrorKind::Eof),
        (b"[", JsonErrorKind::Eof),
        (b"[1,", JsonErrorKind::Eof),
        (b"{\"a\": [1, 2", JsonErrorKind::Eof),
        (b"\"end with backslash\\", JsonErrorKind::Eof),
        (b"12.", JsonErrorKind::Expected("fraction digit")),
        (b"1e", JsonErrorKind::Expected("exponent digit")),
        (b"1e+", JsonErrorKind::Expected("exponent digit")),
        (b"-", JsonErrorKind::Expected("digit")),
        (b"tru", JsonErrorKind::BadLiteral),
        (b"nul", JsonErrorKind::BadLiteral),
        (b"falsy", JsonErrorKind::BadLiteral),
    ];
    for (doc, want) in corpus {
        let err = kind_of(doc);
        assert_eq!(
            &err.kind,
            want,
            "doc {:?} → {err}",
            String::from_utf8_lossy(doc)
        );
        assert!(err.pos <= doc.len(), "offset inside the document: {err}");
    }
}

#[test]
fn depth_bombs_are_bounded_not_a_stack_overflow() {
    for bomb in [
        "[".repeat(10_000),
        "{\"a\":".repeat(10_000),
        format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1)),
    ] {
        let err = kind_of(bomb.as_bytes());
        assert_eq!(err.kind, JsonErrorKind::TooDeep, "bomb → {err}");
    }
    // Exactly at the bound still parses.
    let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
    assert!(parse_bytes(ok.as_bytes()).is_ok(), "MAX_DEPTH itself is legal");
}

#[test]
fn invalid_utf8_is_rejected_with_its_offset() {
    let err = kind_of(b"\"\xff\xfe\"");
    assert_eq!(err.kind, JsonErrorKind::BadUtf8);
    assert_eq!(err.pos, 1, "offset points at the bad byte: {err}");
    // Same rejection on the slow (escaped) path.
    let err = kind_of(b"\"a\\n\xff\"");
    assert_eq!(err.kind, JsonErrorKind::BadUtf8);
}

#[test]
fn huge_numbers_never_become_inf() {
    let four_hundred_digits = format!("1{}", "0".repeat(400));
    for doc in ["1e999", "-1e309", "2e308", four_hundred_digits.as_str()] {
        let err = kind_of(doc.as_bytes());
        assert_eq!(err.kind, JsonErrorKind::BadNumber, "{doc} → {err}");
        assert_eq!(err.pos, 0, "error anchored at the number start: {err}");
    }
    // Large but finite is fine.
    assert!(parse_bytes(b"1e308").is_ok());
}

#[test]
fn bad_escapes_and_control_chars() {
    let err = kind_of(b"\"a\\q\"");
    assert_eq!(err.kind, JsonErrorKind::BadEscape);
    assert_eq!(err.pos, 2, "offset at the backslash: {err}");

    assert_eq!(kind_of(b"\"\\u00\"").kind, JsonErrorKind::BadUnicode);
    assert_eq!(kind_of(b"\"\\uzzzz\"").kind, JsonErrorKind::BadUnicode);
    assert_eq!(kind_of(b"\"\\ud800\"").kind, JsonErrorKind::BadUnicode, "lone surrogate");
    assert_eq!(kind_of(b"\"\\ud800\\u0041\"").kind, JsonErrorKind::BadUnicode);

    let err = kind_of(b"\"a\x01b\"");
    assert_eq!(err.kind, JsonErrorKind::ControlChar);
    assert_eq!(err.pos, 2, "offset at the raw control byte: {err}");
}

#[test]
fn trailing_garbage_and_strict_grammar() {
    let err = kind_of(b"{} x");
    assert_eq!(err.kind, JsonErrorKind::Trailing);
    assert_eq!(err.pos, 3, "{err}");

    assert_eq!(kind_of(b"1 2").kind, JsonErrorKind::Trailing);
    assert_eq!(kind_of(b"[1,2] []").kind, JsonErrorKind::Trailing);
    // Leading zeros are two tokens under the strict grammar.
    assert_eq!(kind_of(b"01").kind, JsonErrorKind::Trailing);

    assert_eq!(kind_of(b"+1").kind, JsonErrorKind::Expected("value"));
    assert_eq!(kind_of(b".5").kind, JsonErrorKind::Expected("value"));
    let err = kind_of(b"[1, oops]");
    assert_eq!(err.kind, JsonErrorKind::Expected("value"));
    assert_eq!(err.pos, 4, "{err}");
}

#[test]
fn errors_render_with_offsets_through_anyhow() {
    // The `&str` entry point chains the typed error into `anyhow` with
    // the offset intact — this is what reaches logs and 400 bodies.
    let msg = format!("{:#}", parse("{\"a\": }").expect_err("rejected"));
    assert!(msg.contains("offset"), "rendered error carries the offset: {msg}");
}

#[test]
fn streaming_arrays_enforce_budgets_without_panicking() {
    let mut out = Vec::new();
    let mut lex = Lexer::new(b"[1,2,3,4,5]");
    let err = lex
        .f32_array_into(&mut out, 3)
        .expect_err("budget of 3 must reject 5 elements");
    assert_eq!(err.kind, JsonErrorKind::TooLarge);

    // usize arrays reject negatives and fractions (they would alias to
    // nonsense shapes if truncated silently).
    for doc in [&b"[-1]"[..], b"[1.5]", b"[1e999]"] {
        let mut shape = Vec::new();
        let mut lex = Lexer::new(doc);
        assert!(
            lex.usize_array_into(&mut shape, 16).is_err(),
            "{:?} must be rejected as a usize array",
            String::from_utf8_lossy(doc)
        );
    }
}

#[test]
fn well_formed_documents_still_parse() {
    // The corpus must not have made the lexer paranoid: a normal
    // document round-trips, and escape-free strings borrow.
    let doc = b"{\"a\": [1, 2.5, -3e2], \"s\": \"hi\", \"b\": true, \"n\": null}";
    let v = parse_bytes(doc).expect("well-formed parses");
    assert_eq!(v.get("s").as_str(), Some("hi"));
    match v {
        Json::Obj(_) => {}
        other => panic!("expected object, got {other:?}"),
    }

    let mut lex = Lexer::new(b"\"plain\"");
    assert!(lex.string().expect("parses").is_borrowed());
    let mut lex = Lexer::new(b"\"esc\\n\"");
    assert!(!lex.string().expect("parses").is_borrowed());
}
