//! Integration: the Rust K-means toolkit against the Python pipeline's
//! artifacts. Same algorithm (quantile-init 1-D Lloyd), same data —
//! centroids and reconstruction quality must agree.

mod common;

use clusterformer::clustering::{ClusterScheme, Quantizer};
use clusterformer::model::Registry;

#[test]
fn rust_quantizer_matches_python_artifacts() {
    if !common::artifacts_available("rust_quantizer_matches_python_artifacts") {
        return;
    }
    let mut registry = Registry::load("artifacts").expect("run `make artifacts`");
    let entry = registry.manifest.model("vit").unwrap().clone();
    let names = entry.clustered_names();
    let weights = registry.weights("vit").unwrap().clone();

    for (scheme, c) in [
        (ClusterScheme::PerLayer, 64),
        (ClusterScheme::Entire, 16),
    ] {
        let rust = Quantizer::new(c, scheme).run(&names, &weights).unwrap();
        let python = registry.clustered("vit", scheme, c).unwrap();

        // Reconstruction error must agree closely (identical algorithm,
        // float32 vs float64 accumulation differences only).
        let mse_rs = rust.quantization_mse(&weights).unwrap();
        let mse_py = python.quantization_mse(&weights).unwrap();
        let rel = (mse_rs - mse_py).abs() / mse_py;
        assert!(
            rel < 0.05,
            "{} c={c}: rust mse {mse_rs:.4e} vs python {mse_py:.4e} ({rel:.3} rel)",
            scheme.name()
        );

        // Centroid tables must align row-by-row.
        let cb_rs = rust.codebooks.as_f32().unwrap();
        let cb_py = python.codebooks.as_f32().unwrap();
        assert_eq!(cb_rs.len(), cb_py.len());
        let spread = cb_py
            .iter()
            .fold(0.0f32, |m, &v| m.max(v.abs()))
            .max(1e-6);
        let mut worst = 0.0f32;
        for (a, b) in cb_rs.iter().zip(&cb_py) {
            worst = worst.max((a - b).abs());
        }
        assert!(
            worst < spread * 0.05,
            "{} c={c}: centroid tables diverge (max |Δ| {worst}, spread {spread})",
            scheme.name()
        );
    }
}

#[test]
fn table_bytes_match_manifest() {
    if !common::artifacts_available("table_bytes_match_manifest") {
        return;
    }
    let mut registry = Registry::load("artifacts").unwrap();
    let entry = registry.manifest.model("vit").unwrap().clone();
    let names = entry.clustered_names();
    let weights = registry.weights("vit").unwrap().clone();
    for (scheme, c, key) in [
        (ClusterScheme::Entire, 64, "entire_64"),
        (ClusterScheme::PerLayer, 64, "perlayer_64"),
    ] {
        let rust = Quantizer::new(c, scheme).run(&names, &weights).unwrap();
        assert_eq!(
            rust.table_bytes(),
            entry.table_bytes[key],
            "table accounting must match the python manifest for {key}"
        );
    }
}

#[test]
fn python_indices_reference_only_live_rows() {
    if !common::artifacts_available("python_indices_reference_only_live_rows") {
        return;
    }
    // Every u8 index in the python artifact must be < n_clusters.
    let registry = Registry::load("artifacts").unwrap();
    let ct = registry
        .clustered("vit", ClusterScheme::PerLayer, 16)
        .unwrap();
    for (name, t) in &ct.indices {
        let max = t.as_u8().unwrap().iter().copied().max().unwrap_or(0);
        assert!(max < 16, "{name}: index {max} out of range for c=16");
    }
}
