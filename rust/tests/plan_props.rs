//! Property tests for the memory planner (ISSUE 3):
//!
//! * planned (arena) execution is **bit-for-bit** equal to the classic
//!   per-instruction-buffer evaluator on randomized small graphs built
//!   from elementwise chains (in-place candidates), reshape/transpose
//!   round-trips (zero-copy aliases), softmax-style reduce/broadcast,
//!   dots, slices/concats, compare/select, and convert round-trips;
//! * the same holds for clustered-dot modules, full-input and
//!   weight-resident (prepared packed weights);
//! * liveness safety — "never free a slot a later instruction reads" —
//!   is replayed structurally by `MemoryPlan`'s build-time verifier on
//!   every one of these random graphs: a violation fails the build, and
//!   a fallback would surface here as `memory_plan() == None`.

use std::collections::HashMap;
use std::sync::Arc;

use clusterformer::clustering::{ClusterScheme, Quantizer};
use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::{evaluate_unplanned, InterpExecutor};
use clusterformer::runtime::{Executor as _, ResidentExecutor as _, ThreadBudget};
use clusterformer::tensor::Tensor;
use clusterformer::testing::prop::{check, Gen};
use clusterformer::util::rng::Pcg32;

/// Incrementally generated module: every value is f32 `[m, n]`; weights
/// are f32 `[n, n]`.
struct GraphGen {
    m: usize,
    n: usize,
    body: String,
    vals: Vec<String>,
    next: usize,
}

impl GraphGen {
    fn new(m: usize, n: usize) -> GraphGen {
        GraphGen {
            m,
            n,
            body: String::new(),
            vals: vec!["x0".into(), "x1".into()],
            next: 0,
        }
    }

    fn fresh(&mut self) -> String {
        self.next += 1;
        format!("v{}", self.next)
    }

    fn pick(&self, g: &mut Gen) -> String {
        self.vals[g.usize(0, self.vals.len() - 1)].clone()
    }

    fn emit(&mut self, line: String) {
        self.body.push_str("  ");
        self.body.push_str(&line);
        self.body.push('\n');
    }

    fn add_pattern(&mut self, g: &mut Gen) {
        let (m, n) = (self.m, self.n);
        let mn = format!("f32[{m},{n}]{{1,0}}");
        match g.usize(0, 8) {
            0 => {
                // unary elementwise (in-place candidate)
                let x = self.pick(g);
                let y = self.fresh();
                let op = *g.pick(&["exponential", "tanh", "negate", "abs"]);
                self.emit(format!("%{y} = {mn} {op}(%{x})"));
                self.vals.push(y);
            }
            1 => {
                // binary elementwise
                let a = self.pick(g);
                let b = self.pick(g);
                let y = self.fresh();
                let op = *g.pick(&["add", "multiply", "subtract", "maximum"]);
                self.emit(format!("%{y} = {mn} {op}(%{a}, %{b})"));
                self.vals.push(y);
            }
            2 => {
                // reshape round-trip (zero-copy aliases)
                let x = self.pick(g);
                let t = self.fresh();
                let y = self.fresh();
                self.emit(format!("%{t} = f32[{}]{{0}} reshape(%{x})", m * n));
                self.emit(format!("%{y} = {mn} reshape(%{t})"));
                self.vals.push(y);
            }
            3 => {
                // softmax-style normalize: reduce + broadcast + divide
                let x = self.pick(g);
                let (z, r, rb, y) =
                    (self.fresh(), self.fresh(), self.fresh(), self.fresh());
                self.emit(format!("%{z} = f32[] constant(0)"));
                self.emit(format!(
                    "%{r} = f32[{m}]{{0}} reduce(%{x}, %{z}), dimensions={{1}}, to_apply=%add_f"
                ));
                self.emit(format!(
                    "%{rb} = {mn} broadcast(%{r}), dimensions={{0}}"
                ));
                self.emit(format!("%{y} = {mn} divide(%{x}, %{rb})"));
                self.vals.push(y);
            }
            4 => {
                // projection through a weight param
                let x = self.pick(g);
                let y = self.fresh();
                let w = *g.pick(&["w0", "w1"]);
                self.emit(format!(
                    "%{y} = {mn} dot(%{x}, %{w}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}"
                ));
                self.vals.push(y);
            }
            5 => {
                // transpose round-trip
                let x = self.pick(g);
                let t = self.fresh();
                let y = self.fresh();
                self.emit(format!(
                    "%{t} = f32[{n},{m}]{{1,0}} transpose(%{x}), dimensions={{1,0}}"
                ));
                self.emit(format!(
                    "%{y} = {mn} transpose(%{t}), dimensions={{1,0}}"
                ));
                self.vals.push(y);
            }
            6 => {
                // split rows and concatenate back
                let x = self.pick(g);
                let k = g.usize(1, m - 1);
                let (s1, s2, y) = (self.fresh(), self.fresh(), self.fresh());
                self.emit(format!(
                    "%{s1} = f32[{k},{n}]{{1,0}} slice(%{x}), slice={{[0:{k}], [0:{n}]}}"
                ));
                self.emit(format!(
                    "%{s2} = f32[{},{n}]{{1,0}} slice(%{x}), slice={{[{k}:{m}], [0:{n}]}}",
                    m - k
                ));
                self.emit(format!(
                    "%{y} = {mn} concatenate(%{s1}, %{s2}), dimensions={{0}}"
                ));
                self.vals.push(y);
            }
            7 => {
                // compare + select
                let a = self.pick(g);
                let b = self.pick(g);
                let (p, y) = (self.fresh(), self.fresh());
                self.emit(format!(
                    "%{p} = pred[{m},{n}]{{1,0}} compare(%{a}, %{b}), direction=GT"
                ));
                self.emit(format!("%{y} = {mn} select(%{p}, %{a}, %{b})"));
                self.vals.push(y);
            }
            _ => {
                // convert round-trip (f32 -> s32 -> f32)
                let x = self.pick(g);
                let (c, y) = (self.fresh(), self.fresh());
                self.emit(format!("%{c} = s32[{m},{n}]{{1,0}} convert(%{x})"));
                self.emit(format!("%{y} = {mn} convert(%{c})"));
                self.vals.push(y);
            }
        }
    }

    fn finish(self, tuple_root: bool) -> String {
        let (m, n) = (self.m, self.n);
        let last = self.vals.last().unwrap();
        let (res_ty, root) = if tuple_root {
            (
                format!("(f32[{m},{n}])"),
                format!("  ROOT %t = (f32[{m},{n}]{{1,0}}) tuple(%{last})\n"),
            )
        } else {
            // Re-point ROOT at a fresh negate so the root is always a
            // unique instruction name.
            (
                format!("f32[{m},{n}]"),
                format!("  ROOT %rt = f32[{m},{n}]{{1,0}} negate(%{last})\n"),
            )
        };
        format!(
            "HloModule prop\n\
             %add_f (p0: f32[], p1: f32[]) -> f32[] {{\n  \
             %p0 = f32[] parameter(0)\n  \
             %p1 = f32[] parameter(1)\n  \
             ROOT %r = f32[] add(%p0, %p1)\n}}\n\
             ENTRY %e (x0: f32[{m},{n}], x1: f32[{m},{n}], w0: f32[{n},{n}], w1: f32[{n},{n}]) -> {res_ty} {{\n\
             \x20 %x0 = f32[{m},{n}]{{1,0}} parameter(0)\n\
             \x20 %x1 = f32[{m},{n}]{{1,0}} parameter(1)\n\
             \x20 %w0 = f32[{n},{n}]{{1,0}} parameter(2)\n\
             \x20 %w1 = f32[{n},{n}]{{1,0}} parameter(3)\n\
             {}{root}}}\n",
            self.body
        )
    }
}

fn rand_tensor(g: &mut Gen, dims: &[usize], scale: f32) -> Tensor {
    let n: usize = dims.iter().product();
    let vals: Vec<f32> = (0..n).map(|_| g.f32_normal() * scale).collect();
    Tensor::from_f32(dims.to_vec(), &vals).unwrap()
}

#[test]
fn prop_planned_matches_unplanned_on_random_graphs() {
    check("planned == unplanned (random graphs)", 40, |g| {
        let m = g.usize(2, 5);
        let n = g.usize(2, 5);
        let mut gg = GraphGen::new(m, n);
        let steps = g.usize(1, 8);
        for _ in 0..steps {
            gg.add_pattern(g);
        }
        let tuple_root = g.bool();
        let hlo = gg.finish(tuple_root);

        let inputs = vec![
            rand_tensor(g, &[m, n], 0.7),
            rand_tensor(g, &[m, n], 0.7),
            rand_tensor(g, &[n, n], 0.4),
            rand_tensor(g, &[n, n], 0.4),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();

        let module = HloModule::parse(&hlo).unwrap();
        let unplanned = evaluate_unplanned(&module, &refs).unwrap();
        // Sweep kernel thread budgets: the arena path must be bit-for-bit
        // equal to the classic evaluator at every budget.
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "prop")
                .unwrap_or_else(|e| panic!("load failed: {e:#}\n{hlo}"))
                .with_threads(ThreadBudget::new(budget));
            assert!(
                exe.memory_plan().is_some(),
                "random graph must be plannable (liveness verifier rejected it?)\n{hlo}"
            );
            let planned = exe.run(&inputs).unwrap_or_else(|e| {
                panic!("planned run failed: {e:#}\n{hlo}");
            });
            assert_eq!(
                planned, unplanned,
                "planned and unplanned outputs diverged (budget {budget})\n{hlo}"
            );
        }
    });
}

/// The clustered-matmul lowering (u8 indices -> convert -> gather ->
/// dot) on random data: the planned LUT path must match the classic
/// evaluator bit-for-bit, full-input and weight-resident.
#[test]
fn prop_planned_clustered_dot_matches_unplanned() {
    check("planned clustered dot == unplanned", 25, |g| {
        let m = g.usize(1, 5);
        let k = g.usize(2, 7);
        let n = g.usize(1, 6);
        let clusters = *g.pick(&[4usize, 8, 16]);
        let hlo = format!(
            "HloModule clustered_prop\n\
             ENTRY %main (x: f32[{m},{k}], cbs: f32[1,256], idx: u8[{k},{n}]) -> (f32[{m},{n}]) {{\n  \
             %x = f32[{m},{k}]{{1,0}} parameter(0)\n  \
             %cbs = f32[1,256]{{1,0}} parameter(1)\n  \
             %idx = u8[{k},{n}]{{1,0}} parameter(2)\n  \
             %sl = f32[1,256]{{1,0}} slice(%cbs), slice={{[0:1], [0:256]}}\n  \
             %row = f32[256]{{0}} reshape(%sl)\n  \
             %cvt = s32[{k},{n}]{{1,0}} convert(%idx)\n  \
             %w = f32[{k},{n}]{{1,0}} gather(%row, %cvt), offset_dims={{}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim=2, slice_sizes={{1}}\n  \
             %d = f32[{m},{n}]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n  \
             ROOT %t = (f32[{m},{n}]{{1,0}}) tuple(%d)\n}}\n"
        );
        // A real quantizer run produces the codebook/index pair.
        let mut rng = Pcg32::new(g.u64());
        let wvals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let dense = Tensor::from_f32(vec![k, n], &wvals).unwrap();
        let names = vec!["w".to_string()];
        let mut tensors = HashMap::new();
        tensors.insert("w".to_string(), dense);
        let ct = Quantizer::new(clusters, ClusterScheme::PerLayer)
            .run(&names, &tensors)
            .unwrap();
        let x = rand_tensor(g, &[m, k], 0.8);
        let inputs = vec![x.clone(), ct.codebooks.clone(), ct.indices["w"].clone()];
        let refs: Vec<&Tensor> = inputs.iter().collect();

        let module = HloModule::parse(&hlo).unwrap();
        let unplanned = evaluate_unplanned(&module, &refs).unwrap();
        let ct = Arc::new(ct);
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "clustered-prop")
                .unwrap()
                .with_threads(ThreadBudget::new(budget));
            assert!(exe.memory_plan().is_some());
            let planned = exe.run(&inputs).unwrap();
            assert_eq!(
                planned, unplanned,
                "full-input clustered path diverged (budget {budget})"
            );

            // Weight-resident: prepared (bit-packed) weights, planned arena.
            let resident = exe
                .resident(
                    1,
                    Arc::new(vec![ct.codebooks.clone(), ct.indices["w"].clone()]),
                    Some(ct.clone()),
                )
                .unwrap();
            let res = resident.run(std::slice::from_ref(&x)).unwrap();
            assert_eq!(res, unplanned, "resident clustered path diverged (budget {budget})");
        }
    });
}
