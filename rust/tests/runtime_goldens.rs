//! Integration: the Rust runtime's numerics against the Python goldens.
//!
//! `aot.py` exported the first 32 validation images with logits computed
//! through the pure-jnp reference model. Here the same images go through
//! the kernel-path HLO on the configured execution backend
//! (`CLUSTERFORMER_BACKEND`, default: the pure-Rust interpreter); logits
//! must agree to float tolerance for both the baseline and the clustered
//! representation. Skips (visibly) when `artifacts/` is absent.

mod common;

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::worker::VariantExecutor;
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::default_backend;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_model(model: &str) {
    let backend = default_backend().expect("backend");
    let mut registry = Registry::load("artifacts").expect("artifacts (run `make artifacts`)");
    let (images, _labels, base_golden, clus_golden) =
        registry.goldens(model).expect("goldens");
    let n = images.shape()[0];
    let classes = base_golden.shape()[1];

    // --- baseline ---
    let exec =
        VariantExecutor::load(backend.as_ref(), &mut registry, model, VariantKey::Baseline)
            .expect("load baseline");
    let golden = base_golden.as_f32().unwrap();
    let mut worst = 0.0f32;
    let mut i = 0;
    while i < n {
        let hi = (i + 8).min(n);
        let chunk = images.slice_rows(i, hi).unwrap();
        let (rows, _) = exec.execute(&chunk).expect("execute baseline");
        for (j, row) in rows.iter().enumerate() {
            let g = &golden[(i + j) * classes..(i + j + 1) * classes];
            worst = worst.max(max_abs_diff(row, g));
        }
        i = hi;
    }
    assert!(
        worst < 2e-3,
        "{model} baseline logits diverge from python goldens: max |Δ| = {worst}"
    );

    // --- clustered perlayer/64 ---
    let exec = VariantExecutor::load(
        backend.as_ref(),
        &mut registry,
        model,
        VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
    )
    .expect("load clustered");
    let golden = clus_golden.as_f32().unwrap();
    let mut worst = 0.0f32;
    let mut i = 0;
    while i < n {
        let hi = (i + 8).min(n);
        let chunk = images.slice_rows(i, hi).unwrap();
        let (rows, _) = exec.execute(&chunk).expect("execute clustered");
        for (j, row) in rows.iter().enumerate() {
            let g = &golden[(i + j) * classes..(i + j + 1) * classes];
            worst = worst.max(max_abs_diff(row, g));
        }
        i = hi;
    }
    assert!(
        worst < 2e-3,
        "{model} clustered logits diverge from python goldens: max |Δ| = {worst}"
    );
}

#[test]
fn vit_matches_python_goldens() {
    if !common::artifacts_available("vit_matches_python_goldens") {
        return;
    }
    check_model("vit");
}

#[test]
fn deit_matches_python_goldens() {
    if !common::artifacts_available("deit_matches_python_goldens") {
        return;
    }
    check_model("deit");
}

#[test]
fn batch_padding_does_not_change_logits() {
    if !common::artifacts_available("batch_padding_does_not_change_logits") {
        return;
    }
    // A 3-image batch rides in the 8-slot executable zero-padded; its
    // logits must equal the same images in a full batch.
    let backend = default_backend().unwrap();
    let mut registry = Registry::load("artifacts").unwrap();
    let (images, _, _, _) = registry.goldens("vit").unwrap();
    let exec =
        VariantExecutor::load(backend.as_ref(), &mut registry, "vit", VariantKey::Baseline)
            .unwrap();
    let full = images.slice_rows(0, 8).unwrap();
    let (rows_full, b_full) = exec.execute(&full).unwrap();
    assert_eq!(b_full, 8);
    let small = images.slice_rows(0, 3).unwrap();
    let (rows_small, b_small) = exec.execute(&small).unwrap();
    assert_eq!(b_small, 8); // padded to the 8-slot executable
    assert_eq!(rows_small.len(), 3);
    for (a, b) in rows_small.iter().zip(rows_full.iter().take(3)) {
        assert!(max_abs_diff(a, b) < 1e-4);
    }
}

#[test]
fn single_image_batch_works() {
    if !common::artifacts_available("single_image_batch_works") {
        return;
    }
    let backend = default_backend().unwrap();
    let mut registry = Registry::load("artifacts").unwrap();
    let (images, _, _, _) = registry.goldens("vit").unwrap();
    let exec =
        VariantExecutor::load(backend.as_ref(), &mut registry, "vit", VariantKey::Baseline)
            .unwrap();
    let one = images.slice_rows(0, 1).unwrap();
    let (rows, b) = exec.execute(&one).unwrap();
    assert_eq!(b, 1); // the batch-1 executable, no padding
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].len(), exec.n_classes);
}
