//! Integration: the full serving stack — server startup, routing,
//! batching, execution, metrics, rejection, shutdown — against the real
//! execution backend (`CLUSTERFORMER_BACKEND`, default: the pure-Rust
//! interpreter) and artifacts. Skips (visibly) when `artifacts/` is
//! absent.

mod common;

use std::time::Duration;

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::{
    BatchPolicy, BatcherConfig, Server, ServerConfig,
};
use clusterformer::model::{Registry, VariantKey};
use clusterformer::tensor::Tensor;

fn single_image(images: &Tensor, row: usize) -> Tensor {
    let mut img = images.slice_rows(row, row + 1).unwrap();
    let shape = img.shape()[1..].to_vec();
    img.reshape(shape).unwrap();
    img
}

fn start_server(policy: BatchPolicy) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: "artifacts".into(),
        backend: clusterformer::runtime::BackendKind::from_env().unwrap(),
        targets: vec![(
            "vit".to_string(),
            VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
        )],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            policy,
            queue_cap: 64,
        },
        threads: clusterformer::runtime::ThreadBudget::from_env(),
        resilience: Default::default(),
    })
    .expect("server start (run `make artifacts` first)")
}

#[test]
fn serves_requests_with_correct_answers() {
    if !common::artifacts_available("serves_requests_with_correct_answers") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    let (images, labels) = registry.val_set().unwrap();
    let server = start_server(BatchPolicy::Adaptive);

    let n = 24;
    let mut rxs = Vec::new();
    for i in 0..n {
        let img = single_image(&images, i);
        rxs.push(server.router.submit("vit/perlayer_64", img).unwrap());
    }
    let mut correct = 0;
    for (i, (id, rx)) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.id, id);
        assert_eq!(resp.logits.len(), registry.manifest.n_classes);
        assert!(resp.latency_s > 0.0);
        assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        assert!(resp.served_by.starts_with("vit/perlayer_64"));
        if resp.predicted == labels[i] as usize {
            correct += 1;
        }
    }
    // clustered-64 model is ~93% top-1; 24 requests should be mostly right
    assert!(correct >= 18, "only {correct}/24 correct");

    let snap = server.snapshot();
    let v = &snap.per_variant["vit/perlayer_64"];
    assert_eq!(v.requests, n as u64);
    assert!(v.batches >= 3, "expected batching to occur");
    assert_eq!(v.rejected, 0);
    server.shutdown();
}

#[test]
fn unknown_target_rejected_immediately() {
    if !common::artifacts_available("unknown_target_rejected_immediately") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    let (images, _) = registry.val_set().unwrap();
    let server = start_server(BatchPolicy::Deadline);
    let img = single_image(&images, 0);
    assert!(server.router.submit("vit/bogus", img).is_err());
    server.shutdown();
}

#[test]
fn shutdown_flushes_inflight_requests() {
    if !common::artifacts_available("shutdown_flushes_inflight_requests") {
        return;
    }
    let registry = Registry::load("artifacts").unwrap();
    let (images, _) = registry.val_set().unwrap();
    // SizeOnly with a large max_batch: requests sit in the queue until
    // shutdown's flush path executes them.
    let server = Server::start(ServerConfig {
        artifacts_dir: "artifacts".into(),
        backend: clusterformer::runtime::BackendKind::from_env().unwrap(),
        targets: vec![("vit".to_string(), VariantKey::Baseline)],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(3600),
            policy: BatchPolicy::SizeOnly,
            queue_cap: 64,
        },
        threads: clusterformer::runtime::ThreadBudget::from_env(),
        resilience: Default::default(),
    })
    .unwrap();
    let mut rxs = Vec::new();
    for i in 0..3 {
        rxs.push(
            server
                .router
                .submit("vit/baseline", single_image(&images, i))
                .unwrap()
                .1,
        );
    }
    // Give the worker a moment to enqueue, then shut down: the flush must
    // still answer all three.
    std::thread::sleep(Duration::from_millis(300));
    server.shutdown();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("flushed reply");
        assert!(!resp.logits.is_empty());
    }
}
