//! Property tests for the matmul subsystem (ISSUE 2, thread budgets from
//! ISSUE 4):
//!
//! * the blocked GEMM matches the naive index-walk `dot` **bit-for-bit**
//!   across random shapes, batch dims, and axis permutations (both
//!   kernels accumulate over k in the same ascending order) — at every
//!   kernel thread budget in {1, 2, 4};
//! * the clustered LUT matmul matches a dequantize-then-dot reference
//!   within reassociation error, and its pooled fan-out is bit-identical
//!   across budgets (including problems large enough to really fan out);
//! * `pack_indices`/`unpack_indices` round-trip at 4/6/8 bits.

use clusterformer::clustering::packing::{pack_indices, packed_len, unpack_indices};
use clusterformer::runtime::interp::clustered::{lut_matmul_packed, lut_matmul_u8, prepare};
use clusterformer::runtime::interp::gemm::{dot_general, dot_general_naive, DotSpec};
use clusterformer::tensor::Tensor;
use clusterformer::testing::prop::{check, Gen};

fn rand_tensor(g: &mut Gen, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    let vals: Vec<f32> = (0..n).map(|_| g.f32_normal()).collect();
    Tensor::from_f32(dims.to_vec(), &vals).unwrap()
}

#[test]
fn prop_blocked_gemm_matches_naive_2d() {
    check("blocked GEMM == naive dot (2d)", 60, |g| {
        let m = g.usize(1, 12);
        let k = g.usize(1, 20);
        let n = g.usize(1, 12);
        let lhs = rand_tensor(g, &[m, k]);
        let rhs = rand_tensor(g, &[k, n]);
        let spec = DotSpec {
            lhs_contracting: vec![1],
            rhs_contracting: vec![0],
            ..Default::default()
        };
        let naive = dot_general_naive(&lhs, &rhs, &spec).unwrap();
        for threads in [1usize, 2, 4] {
            let fast = dot_general(&lhs, &rhs, &spec, threads).unwrap();
            assert_eq!(fast, naive, "threads={threads}");
        }
    });
}

#[test]
fn prop_blocked_gemm_matches_naive_batched_permuted() {
    // Covers every spec shape the ViT graphs use: plain matmul, batched
    // matmul, attention q@k^T (rhs contracted on its trailing dim, so
    // the rhs needs a canonicalizing repack), and lhs-transposed.
    check("blocked GEMM == naive dot (batched/permuted)", 60, |g| {
        let b = g.usize(1, 3);
        let m = g.usize(1, 6);
        let k = g.usize(1, 8);
        let n = g.usize(1, 6);
        let case = g.usize(0, 2);
        let (ld, rd, spec) = match case {
            // batched [b,m,k] x [b,k,n]
            0 => (
                vec![b, m, k],
                vec![b, k, n],
                DotSpec {
                    lhs_contracting: vec![2],
                    rhs_contracting: vec![1],
                    lhs_batch: vec![0],
                    rhs_batch: vec![0],
                },
            ),
            // q@k^T: [b,m,k] x [b,n,k]
            1 => (
                vec![b, m, k],
                vec![b, n, k],
                DotSpec {
                    lhs_contracting: vec![2],
                    rhs_contracting: vec![2],
                    lhs_batch: vec![0],
                    rhs_batch: vec![0],
                },
            ),
            // lhs contracted on its leading dim: [k,m] x [k,n]
            _ => (
                vec![k, m],
                vec![k, n],
                DotSpec {
                    lhs_contracting: vec![0],
                    rhs_contracting: vec![0],
                    ..Default::default()
                },
            ),
        };
        let lhs = rand_tensor(g, &ld);
        let rhs = rand_tensor(g, &rd);
        let naive = dot_general_naive(&lhs, &rhs, &spec).unwrap();
        for threads in [1usize, 2, 4] {
            let fast = dot_general(&lhs, &rhs, &spec, threads).unwrap();
            assert_eq!(fast, naive, "case {case} dims {ld:?} x {rd:?} threads={threads}");
        }
    });
}

#[test]
fn prop_clustered_lut_matches_dequantized_reference() {
    check("clustered LUT == dequantize+dot", 40, |g| {
        let m = g.usize(1, 8);
        let k = g.usize(1, 24);
        let n = g.usize(1, 10);
        let clusters = *g.pick(&[4usize, 16, 64, 256]);
        let x: Vec<f32> = (0..m * k).map(|_| g.f32_normal()).collect();
        let idx: Vec<u8> = (0..k * n).map(|_| g.usize(0, clusters - 1) as u8).collect();
        let cb: Vec<f32> = (0..clusters).map(|_| g.f32_normal()).collect();

        // Reference: materialize the weights, then dense dot.
        let w: Vec<f32> = idx.iter().map(|&i| cb[i as usize]).collect();
        let lhs = Tensor::from_f32(vec![m, k], &x).unwrap();
        let rhs = Tensor::from_f32(vec![k, n], &w).unwrap();
        let spec = DotSpec {
            lhs_contracting: vec![1],
            rhs_contracting: vec![0],
            ..Default::default()
        };
        let want = dot_general_naive(&lhs, &rhs, &spec).unwrap().as_f32().unwrap();

        let got_u8 = lut_matmul_u8(&x, m, k, n, &idx, &cb, 1).unwrap();
        let prep = prepare(&idx, k, n, &cb, Some(clusters)).unwrap();
        let got_packed = lut_matmul_packed(&x, m, &prep, 1).unwrap();
        // The two LUT paths bucket in the same order: identical.
        assert_eq!(got_u8, got_packed);
        // The pooled fan-out must not change a single bit.
        for threads in [2usize, 4] {
            assert_eq!(lut_matmul_u8(&x, m, k, n, &idx, &cb, threads).unwrap(), got_u8);
            assert_eq!(lut_matmul_packed(&x, m, &prep, threads).unwrap(), got_packed);
        }
        // vs the dense reference: equal up to f32 reassociation.
        for (got, want) in got_u8.iter().zip(&want) {
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "LUT {got} vs dense {want} (m={m} k={k} n={n} c={clusters})"
            );
        }
    });
}

#[test]
fn prop_pack_roundtrip_4_6_8_bits() {
    // The widths the paper cares about: 4 bits (16 clusters), 6 bits
    // (the headline 64-cluster config), 8 bits (padded tables).
    check("pack/unpack roundtrip at 4/6/8 bits", 60, |g| {
        let bits = *g.pick(&[4u32, 6, 8]);
        let n = g.usize(0, 400);
        let max = (1usize << bits) - 1;
        let xs: Vec<u8> = (0..n).map(|_| g.usize(0, max) as u8).collect();
        let packed = pack_indices(&xs, bits).unwrap();
        assert_eq!(packed.len(), packed_len(n, bits));
        assert_eq!(unpack_indices(&packed, n, bits).unwrap(), xs);
    });
}

/// Budget sweep on problems large enough to clear the parallel-work
/// thresholds, so budgets 2 and 4 genuinely fan out on the pool — the
/// small property shapes above all stay serial.
#[test]
fn prop_large_dots_bit_identical_across_budgets() {
    check("large GEMM/LUT bit-identical at budgets 1/2/4", 6, |g| {
        let m = g.usize(96, 160);
        let k = g.usize(64, 128);
        let n = g.usize(96, 160);
        let lhs = rand_tensor(g, &[m, k]);
        let rhs = rand_tensor(g, &[k, n]);
        let spec = DotSpec {
            lhs_contracting: vec![1],
            rhs_contracting: vec![0],
            ..Default::default()
        };
        let reference = dot_general(&lhs, &rhs, &spec, 1).unwrap();
        for threads in [2usize, 4] {
            assert_eq!(
                dot_general(&lhs, &rhs, &spec, threads).unwrap(),
                reference,
                "gemm m={m} k={k} n={n} threads={threads}"
            );
        }

        let clusters = 64;
        let x: Vec<f32> = (0..m * k).map(|_| g.f32_normal()).collect();
        let idx: Vec<u8> = (0..k * n).map(|_| g.usize(0, clusters - 1) as u8).collect();
        let cb: Vec<f32> = (0..clusters).map(|_| g.f32_normal()).collect();
        let prep = prepare(&idx, k, n, &cb, Some(clusters)).unwrap();
        let lut1 = lut_matmul_packed(&x, m, &prep, 1).unwrap();
        for threads in [2usize, 4] {
            assert_eq!(
                lut_matmul_packed(&x, m, &prep, threads).unwrap(),
                lut1,
                "lut m={m} k={k} n={n} threads={threads}"
            );
        }
    });
}
