//! End-to-end: the interpreter on a clustered model executes the matmul
//! cluster-natively — compressed weights (u8 indices + codebook) flow
//! from `ClusteredTensors` through the resident executor to the LUT
//! kernel with **zero full-tensor dequantization** on the dot path
//! (asserted via the counter in `ClusteredTensors`). No artifacts
//! needed: the module below is the exact pattern jax lowers for
//! `kernels.clustered_matmul` (codebook row slice + u8 -> s32 convert ->
//! gather -> dot).

use std::collections::HashMap;
use std::sync::Arc;

use clusterformer::clustering::{ClusterScheme, ClusteredTensors, Quantizer};
use clusterformer::runtime::interp::clustered::lut_dot_count;
use clusterformer::runtime::{backend, Backend as _, BackendKind, Executor as _};
use clusterformer::tensor::Tensor;

/// `logits = x @ dequant(idx, codebooks[0]) + bias`, lowered the way the
/// clustered forward pass lowers: the dequantize is an explicit
/// convert/gather chain in the graph.
const CLUSTERED_HLO: &str = "HloModule clustered_mlp\n\
    ENTRY %main (x: f32[4,6], cbs: f32[1,256], idx: u8[6,5], bias: f32[5]) -> (f32[4,5]) {\n  \
    %x = f32[4,6]{1,0} parameter(0)\n  \
    %cbs = f32[1,256]{1,0} parameter(1)\n  \
    %idx = u8[6,5]{1,0} parameter(2)\n  \
    %bias = f32[5]{0} parameter(3)\n  \
    %sl = f32[1,256]{1,0} slice(%cbs), slice={[0:1], [0:256]}\n  \
    %row = f32[256]{0} reshape(%sl)\n  \
    %cvt = s32[6,5]{1,0} convert(%idx)\n  \
    %w = f32[6,5]{1,0} gather(%row, %cvt), offset_dims={}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=2, slice_sizes={1}\n  \
    %d = f32[4,5]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n  \
    %bb = f32[4,5]{1,0} broadcast(%bias), dimensions={1}\n  \
    %add = f32[4,5]{1,0} add(%d, %bb)\n  \
    ROOT %t = (f32[4,5]{1,0}) tuple(%add)\n}\n";

/// The LUT/dequant counters are process-wide; serialize the tests in
/// this binary so their before/after reads don't race.
static COUNTER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn write_module() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "clusterformer-clustered-e2e-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("clustered_mlp.hlo.txt");
    std::fs::write(&path, CLUSTERED_HLO).unwrap();
    path
}

/// Cluster a deterministic [6,5] weight into 8 clusters; returns the
/// representation plus the dense original for reference math.
fn clustered_fixture() -> (ClusteredTensors, Tensor) {
    let w: Vec<f32> = (0..30).map(|i| ((i as f32) * 0.47).sin()).collect();
    let dense = Tensor::from_f32(vec![6, 5], &w).unwrap();
    let names = vec!["w".to_string()];
    let mut tensors = HashMap::new();
    tensors.insert("w".to_string(), dense.clone());
    let ct = Quantizer::new(8, ClusterScheme::PerLayer)
        .run(&names, &tensors)
        .unwrap();
    (ct, dense)
}

fn inputs(ct: &ClusteredTensors) -> (Tensor, Tensor, Tensor, Tensor) {
    let x: Vec<f32> = (0..24).map(|i| ((i as f32) * 0.83).cos()).collect();
    (
        Tensor::from_f32(vec![4, 6], &x).unwrap(),
        ct.codebooks.clone(),
        ct.indices["w"].clone(),
        Tensor::from_f32(vec![5], &[0.1, -0.2, 0.3, -0.4, 0.5]).unwrap(),
    )
}

/// Plain-Rust reference: x @ dequantized-w + bias (independent of the
/// interpreter and of the LUT kernel).
fn reference(x: &Tensor, ct: &ClusteredTensors, bias: &Tensor) -> Vec<f32> {
    let xv = x.as_f32().unwrap();
    let idx = ct.indices["w"].as_u8().unwrap().to_vec();
    let cb = ct.codebooks.as_f32().unwrap();
    let bv = bias.as_f32().unwrap();
    let mut out = vec![0.0f32; 4 * 5];
    for r in 0..4 {
        for c in 0..5 {
            let mut acc = 0.0f32;
            for k in 0..6 {
                acc += xv[r * 6 + k] * cb[idx[k * 5 + c] as usize];
            }
            out[r * 5 + c] = acc + bv[c];
        }
    }
    out
}

#[test]
fn clustered_dot_runs_lut_kernel_without_dequantizing() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let path = write_module();
    let backend = backend(BackendKind::Interp).unwrap();
    let exe = backend.load_hlo(&path).unwrap();
    let (ct, _dense) = clustered_fixture();
    let (x, cbs, idx, bias) = inputs(&ct);
    let want = reference(&x, &ct, &bias);

    let dequants_before = ClusteredTensors::dequant_calls();
    let luts_before = lut_dot_count();

    // Full-input path: plan fires, u8 LUT kernel.
    let out = exe
        .run(&[x.clone(), cbs.clone(), idx.clone(), bias.clone()])
        .unwrap();
    assert_eq!(out[0].shape(), &[4, 5]);
    let got = out[0].as_f32().unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "full path: {g} vs {w}");
    }

    // Weight-resident path with clustered metadata: packed LUT kernel.
    let resident = exe
        .with_resident_clustered(
            1,
            Arc::new(vec![cbs, idx, bias]),
            Some(Arc::new(ct)),
        )
        .unwrap();
    let out2 = resident.run(std::slice::from_ref(&x)).unwrap();
    let got2 = out2[0].as_f32().unwrap();
    for (g, w) in got2.iter().zip(&want) {
        assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "resident path: {g} vs {w}");
    }

    // Both runs went through the LUT kernel...
    assert!(
        lut_dot_count() >= luts_before + 2,
        "expected both dots on the LUT path ({} -> {})",
        luts_before,
        lut_dot_count()
    );
    // ...and neither ever dematerialized a clustered tensor.
    assert_eq!(
        ClusteredTensors::dequant_calls(),
        dequants_before,
        "clustered dot path must perform zero full-tensor dequantization"
    );
}

#[test]
fn multi_use_dequantize_falls_back_to_dense_and_matches() {
    // The gather result feeds the dot AND the root tuple, so the plan
    // must leave this dot on the dense path (skipping the chain would
    // starve the second consumer) — and the numbers must still be right.
    let hlo = "HloModule clustered_multiuse\n\
        ENTRY %main (x: f32[4,6], cbs: f32[1,256], idx: u8[6,5]) -> (f32[4,5], f32[6,5]) {\n  \
        %x = f32[4,6]{1,0} parameter(0)\n  \
        %cbs = f32[1,256]{1,0} parameter(1)\n  \
        %idx = u8[6,5]{1,0} parameter(2)\n  \
        %sl = f32[1,256]{1,0} slice(%cbs), slice={[0:1], [0:256]}\n  \
        %row = f32[256]{0} reshape(%sl)\n  \
        %cvt = s32[6,5]{1,0} convert(%idx)\n  \
        %w = f32[6,5]{1,0} gather(%row, %cvt), offset_dims={}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=2, slice_sizes={1}\n  \
        %d = f32[4,5]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n  \
        ROOT %t = (f32[4,5]{1,0}, f32[6,5]{1,0}) tuple(%d, %w)\n}\n";
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::temp_dir().join(format!(
        "clusterformer-clustered-multiuse-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("multiuse.hlo.txt");
    std::fs::write(&path, hlo).unwrap();

    let backend = backend(BackendKind::Interp).unwrap();
    let exe = backend.load_hlo(&path).unwrap();
    let (ct, _) = clustered_fixture();
    let (x, cbs, idx, _bias) = inputs(&ct);
    let zero_bias = Tensor::from_f32(vec![5], &[0.0; 5]).unwrap();
    let want = reference(&x, &ct, &zero_bias);

    let luts_before = lut_dot_count();
    let out = exe.run(&[x, cbs, idx]).unwrap();
    assert_eq!(lut_dot_count(), luts_before, "multi-use chain must stay dense");
    assert_eq!(out.len(), 2);
    let got = out[0].as_f32().unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
    }
    // The second output is the materialized weight tensor itself.
    assert_eq!(out[1].shape(), &[6, 5]);
    let deq = out[1].as_f32().unwrap();
    let cb = ct.codebooks.as_f32().unwrap();
    let idxv = ct.indices["w"].as_u8().unwrap();
    for (d, &i) in deq.iter().zip(idxv) {
        assert_eq!(*d, cb[i as usize]);
    }
}
