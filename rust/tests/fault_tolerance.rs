//! Chaos tests for the fault-tolerance layer: worker supervision and
//! restart, request deadlines, admission-control shedding, SLO-aware
//! degradation, and permanent failure — all driven deterministically
//! through `coordinator::faults` injectors against synthetic artifacts
//! (no prebuilt models needed).
//!
//! The invariant every test enforces: **every submitted request gets
//! exactly one terminal reply** (Completed / Timeout / Overloaded /
//! Failed) — no hangs, no duplicates, no leaks.
//!
//! Fault rules are keyed by target label process-wide, so each test
//! uses its own model name and they can run concurrently.

use std::time::{Duration, Instant};

use clusterformer::coordinator::{
    faults, BatchPolicy, BatcherConfig, PendingReply, ReplyStatus, ResilienceConfig,
    Router, Server, ServerConfig, SubmitError, SubmitOptions,
};
use clusterformer::model::VariantKey;
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::testing::synthetic::{SyntheticServing, CLASSES};

fn start_server(synth: &SyntheticServing, resilience: ResilienceConfig) -> Server {
    start_server_two(synth, resilience, false)
}

fn start_server_two(
    synth: &SyntheticServing,
    resilience: ResilienceConfig,
    with_clustered: bool,
) -> Server {
    let mut targets = vec![(synth.model.clone(), VariantKey::Baseline)];
    if with_clustered {
        targets.push((synth.model.clone(), SyntheticServing::clustered_key()));
    }
    Server::start(ServerConfig {
        artifacts_dir: synth.dir.clone(),
        targets,
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        threads: ThreadBudget::new(2),
        resilience,
    })
    .expect("synthetic server must start")
}

/// Receive a terminal reply, then assert the exactly-once contract: the
/// second receive must report disconnection, never a duplicate.
fn recv_terminal(rx: &PendingReply) -> clusterformer::coordinator::ClassResponse {
    let resp = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("every request must get a terminal reply");
    assert!(
        rx.recv_timeout(Duration::from_millis(10)).is_err(),
        "request {} answered twice",
        resp.id
    );
    resp
}

fn wait_for_state(
    router: &Router,
    target: &str,
    want: clusterformer::coordinator::router::WorkerState,
) {
    let handle = router.handle(target).expect("target exists");
    let t0 = Instant::now();
    while handle.state() != want {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "{target} never reached {want:?} (state {:?})",
            handle.state()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A worker panic mid-stream: every caller still gets exactly one
/// terminal reply, the supervisor restarts the worker, and the server
/// keeps serving afterwards.
#[test]
fn worker_panic_recovers_and_reconciles() {
    let synth = SyntheticServing::build("chaos");
    let target = synth.baseline_target();
    faults::force_faults(&format!("panic:{target}:3"));
    let server = start_server(
        &synth,
        ResilienceConfig {
            max_restarts: 3,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(50),
            ..ResilienceConfig::default()
        },
    );
    let router = server.router.clone();

    const N: usize = 60;
    let mut pending = Vec::new();
    for i in 0..N {
        pending
            .push(router.submit(&target, SyntheticServing::image(i as u64 + 1)).unwrap().1);
    }
    let mut completed = 0usize;
    let mut failed = 0usize;
    for rx in &pending {
        let resp = recv_terminal(rx);
        match resp.status {
            ReplyStatus::Completed => {
                assert_eq!(resp.logits.len(), CLASSES);
                completed += 1;
            }
            ReplyStatus::Failed => failed += 1,
            other => panic!("unexpected status {other:?}"),
        }
    }
    assert_eq!(completed + failed, N, "totals must reconcile");
    assert!(failed >= 1, "the injected panic must fail at least its own batch");

    // The supervisor records the restart moments after sending the
    // crashed batch's Failed replies, so poll briefly instead of racing
    // it; the counts themselves must then be exact.
    let t0 = Instant::now();
    let v = loop {
        let snap = server.snapshot();
        let v = snap.per_variant[target.as_str()].clone();
        if v.worker_restarts >= 1 || t0.elapsed() > Duration::from_secs(10) {
            break v;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    assert_eq!(v.worker_panics, 1, "exactly one injected panic");
    assert_eq!(v.worker_restarts, 1, "exactly one restart");
    assert_eq!(v.requests, completed as u64);

    // Post-recovery the target must serve again (submits during the
    // restart window may shed — retry until the revived worker answers).
    wait_for_state(&router, &target, clusterformer::coordinator::router::WorkerState::Ready);
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never recovered");
        match router.submit(&target, SyntheticServing::image(999)) {
            Ok((_, rx)) => {
                let resp = recv_terminal(&rx);
                if resp.status == ReplyStatus::Completed {
                    let want = synth.reference_logits(&SyntheticServing::image(999));
                    for (g, e) in resp.logits.iter().zip(&want) {
                        assert!((g - e).abs() <= 1e-4, "post-restart answer wrong");
                    }
                    break;
                }
            }
            Err(SubmitError::Overloaded { .. }) => {}
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        std::thread::sleep(Duration::from_millis(10));
    }

    faults::clear_faults(&target);
    server.shutdown();
    synth.cleanup();
}

/// Requests whose deadline expires while queued get a `Timeout` reply
/// before ever reaching a batch.
#[test]
fn expired_deadlines_get_timeout() {
    let synth = SyntheticServing::build("deadtest");
    let target = synth.baseline_target();
    // Every batch takes ~100ms, so anything queued behind one with a
    // 10ms deadline is reaped.
    faults::force_faults(&format!("slow:{target}:100ms"));
    let server = start_server(&synth, ResilienceConfig::default());
    let router = server.router.clone();

    // A occupies the worker for ~100ms.
    let (_, rx_a) = router.submit(&target, SyntheticServing::image(1)).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    // B (10ms budget) and C (already expired) queue behind A.
    let opts = SubmitOptions {
        deadline: Some(Duration::from_millis(10)),
        ..Default::default()
    };
    let (_, rx_b) = router
        .submit_opts(&target, SyntheticServing::image(2), opts)
        .unwrap();
    let opts = SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() };
    let (_, rx_c) = router
        .submit_opts(&target, SyntheticServing::image(3), opts)
        .unwrap();

    let a = recv_terminal(&rx_a);
    assert_eq!(a.status, ReplyStatus::Completed);
    let b = recv_terminal(&rx_b);
    assert_eq!(b.status, ReplyStatus::Timeout, "B's deadline expired while queued");
    assert!(b.logits.is_empty());
    let c = recv_terminal(&rx_c);
    assert_eq!(c.status, ReplyStatus::Timeout, "C was dead on arrival");

    let snap = server.snapshot();
    assert_eq!(snap.per_variant[target.as_str()].timed_out, 2);

    faults::clear_faults(&target);
    server.shutdown();
    synth.cleanup();
}

/// With a bounded per-target queue, submits beyond the in-flight bound
/// shed with `Overloaded` instead of growing an unbounded backlog — and
/// admitted + shed always equals offered.
#[test]
fn queue_bound_sheds_overloaded() {
    let synth = SyntheticServing::build("bound");
    let target = synth.baseline_target();
    faults::force_faults(&format!("slow:{target}:50ms"));
    let server = start_server(
        &synth,
        ResilienceConfig { queue_bound: 4, ..ResilienceConfig::default() },
    );
    let router = server.router.clone();

    const N: usize = 30;
    let mut pending = Vec::new();
    let mut shed = 0usize;
    for i in 0..N {
        match router.submit(&target, SyntheticServing::image(i as u64 + 1)) {
            Ok((_, rx)) => pending.push(rx),
            Err(SubmitError::Overloaded { target: t }) => {
                assert_eq!(t, target);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed >= 1, "30 instant submits against a bound of 4 must shed");
    assert!(pending.len() >= 4, "the bound's worth of requests must be admitted");
    for rx in &pending {
        let resp = recv_terminal(rx);
        assert_eq!(resp.status, ReplyStatus::Completed, "admitted requests complete");
    }
    assert_eq!(pending.len() + shed, N, "admitted + shed == offered");

    let snap = server.snapshot();
    let v = &snap.per_variant[target.as_str()];
    assert_eq!(v.shed, shed as u64);
    assert_eq!(v.requests, pending.len() as u64);

    // The depth gauge must fully drain: each RAII ticket drops just
    // after its reply send, so give the worker a beat to finish.
    let handle = router.handle(&target).unwrap();
    let t0 = Instant::now();
    while handle.depth() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "RAII tickets must return every slot (depth {})",
            handle.depth()
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    faults::clear_faults(&target);
    server.shutdown();
    synth.cleanup();
}

/// Under SLO pressure the router degrades eligible requests to the
/// cheaper fallback variant (honoring per-request accuracy floors), and
/// routes back to the primary once pressure clears.
#[test]
fn degradation_engages_and_disengages() {
    let synth = SyntheticServing::build("degr");
    let primary = synth.baseline_target();
    let fallback = synth.clustered_target();
    faults::force_faults(&format!("slow:{primary}:40ms"));
    let mut resilience = ResilienceConfig {
        slo: Some(Duration::from_millis(5)),
        window: Duration::from_millis(100),
        hold: Duration::from_millis(50),
        ..ResilienceConfig::default()
    };
    resilience.fallback.insert(primary.clone(), fallback.clone());
    resilience.accuracy.insert(primary.clone(), 0.9);
    resilience.accuracy.insert(fallback.clone(), 0.6);
    let server = start_server_two(&synth, resilience, true);
    let router = server.router.clone();

    // Hammer the slow primary until its recent p95 queue wait crosses
    // the SLO and degradation engages.
    let mut pending = Vec::new();
    let t0 = Instant::now();
    let mut engaged = false;
    let mut i = 0u64;
    while t0.elapsed() < Duration::from_secs(5) {
        pending.push(router.submit(&primary, SyntheticServing::image(i + 1)).unwrap().1);
        i += 1;
        if router.degraded(&primary) {
            engaged = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(engaged, "sustained overload must engage degradation");

    // While engaged: an unconstrained request reroutes to the fallback…
    let (_, rx) = router.submit(&primary, SyntheticServing::image(7001)).unwrap();
    let resp = recv_terminal(&rx);
    assert_eq!(resp.status, ReplyStatus::Completed);
    assert!(
        resp.served_by.starts_with(fallback.as_str()),
        "engaged degradation must reroute to {fallback}, served_by={}",
        resp.served_by
    );
    // …but a request whose accuracy floor the fallback (0.6) cannot meet
    // stays pinned to the primary.
    let opts = SubmitOptions { accuracy_floor: Some(0.8), ..Default::default() };
    let (_, rx) = router
        .submit_opts(&primary, SyntheticServing::image(7002), opts)
        .unwrap();
    let resp = recv_terminal(&rx);
    assert_eq!(resp.status, ReplyStatus::Completed);
    assert!(
        resp.served_by.starts_with(primary.as_str()),
        "accuracy floor above the fallback must pin to {primary}, served_by={}",
        resp.served_by
    );

    let snap = server.snapshot();
    assert!(
        snap.per_variant[primary.as_str()].degraded >= 1,
        "degraded rerouting must be counted against the primary"
    );

    // Drain the backlog, lift the slowness, and let the recent window
    // expire: degradation must disengage and traffic return.
    for rx in &pending {
        recv_terminal(rx);
    }
    faults::clear_faults(&primary);
    let t0 = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(50));
        if !router.degraded(&primary) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "degradation must clear once pressure is gone"
        );
    }
    let (_, rx) = router.submit(&primary, SyntheticServing::image(8001)).unwrap();
    let resp = recv_terminal(&rx);
    assert_eq!(resp.status, ReplyStatus::Completed);
    assert!(
        resp.served_by.starts_with(primary.as_str()),
        "after pressure clears traffic must return to {primary}, served_by={}",
        resp.served_by
    );

    server.shutdown();
    synth.cleanup();
}

/// A worker that crashes more than `max_restarts` times is marked
/// permanently failed; submits then report `ShuttingDown` instead of
/// feeding a crash loop.
#[test]
fn permanent_failure_after_max_restarts() {
    let synth = SyntheticServing::build("permfail");
    let target = synth.baseline_target();
    faults::force_faults(&format!("panic:{target}:1,panic:{target}:2,panic:{target}:3"));
    let server = start_server(
        &synth,
        ResilienceConfig {
            max_restarts: 2,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(40),
            ..ResilienceConfig::default()
        },
    );
    let router = server.router.clone();
    let handle = router.handle(&target).unwrap().clone();

    use clusterformer::coordinator::router::WorkerState;
    let t0 = Instant::now();
    let mut crashes_seen = 0u32;
    while handle.state() != WorkerState::Dead {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "restart budget must eventually exhaust (crashes {crashes_seen})"
        );
        // Feed the worker so its next batch hits the next panic rule;
        // every reply (explicit Failed or synthesized on a dead queue)
        // is still exactly-once.
        match router.submit(&target, SyntheticServing::image(crashes_seen as u64 + 1)) {
            Ok((_, rx)) => {
                let resp = recv_terminal(&rx);
                if resp.status == ReplyStatus::Failed {
                    crashes_seen += 1;
                } else {
                    assert_eq!(resp.status, ReplyStatus::Completed);
                }
            }
            Err(SubmitError::Overloaded { .. }) => {
                // Restart window: the fresh queue is not installed yet.
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(SubmitError::ShuttingDown { .. }) => break,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert_eq!(handle.state(), WorkerState::Dead);

    // A dead target refuses new work explicitly.
    match router.submit(&target, SyntheticServing::image(424242)) {
        Err(SubmitError::ShuttingDown { target: t }) => assert_eq!(t, target),
        other => panic!("expected ShuttingDown from a dead target, got {other:?}"),
    }

    let snap = server.snapshot();
    let v = &snap.per_variant[target.as_str()];
    assert_eq!(v.worker_panics, 3, "all three panic rules fired");
    assert_eq!(v.worker_restarts, 2, "only max_restarts restarts were attempted");

    faults::clear_faults(&target);
    server.shutdown();
    synth.cleanup();
}

/// Env-driven injection (what CI exercises): if `CLUSTERFORMER_FAULTS`
/// targets the `envpanic` model, prove the panic fires and the stack
/// reconciles. Skips visibly otherwise.
#[test]
fn env_injected_panic_reconciles() {
    let spec = match faults::env_spec() {
        Some(s) if s.contains("envpanic/baseline") => s,
        _ => {
            eprintln!(
                "skipping env_injected_panic_reconciles: CLUSTERFORMER_FAULTS does \
                 not target envpanic/baseline"
            );
            return;
        }
    };
    eprintln!("running with CLUSTERFORMER_FAULTS={spec}");
    let synth = SyntheticServing::build("envpanic");
    let target = synth.baseline_target();
    let server = start_server(
        &synth,
        ResilienceConfig {
            backoff_base: Duration::from_millis(10),
            ..ResilienceConfig::default()
        },
    );
    let router = server.router.clone();

    const N: usize = 20;
    let mut pending = Vec::new();
    for i in 0..N {
        pending
            .push(router.submit(&target, SyntheticServing::image(i as u64 + 1)).unwrap().1);
    }
    let mut completed = 0usize;
    let mut failed = 0usize;
    for rx in &pending {
        match recv_terminal(rx).status {
            ReplyStatus::Completed => completed += 1,
            ReplyStatus::Failed => failed += 1,
            other => panic!("unexpected status {other:?}"),
        }
    }
    assert_eq!(completed + failed, N);
    let t0 = Instant::now();
    let v = loop {
        let snap = server.snapshot();
        let v = snap.per_variant[target.as_str()].clone();
        if v.worker_restarts >= v.worker_panics || t0.elapsed() > Duration::from_secs(10) {
            break v;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    assert!(v.worker_panics >= 1, "the env-injected panic must have fired");
    assert_eq!(v.worker_restarts, v.worker_panics, "every crash was restarted");

    server.shutdown();
    synth.cleanup();
}
