//! End-to-end acceptance for memory-planned execution (ISSUE 3):
//!
//! * steady-state planned execution performs **zero** tensor-sized heap
//!   allocations (the `tensor_allocs` counter stays flat across calls
//!   after warmup);
//! * on a ViT-shaped module, planned peak resident intermediate bytes
//!   are <= 50% of the unplanned per-instruction sum;
//! * two resident executors at different batch sizes share ONE pooled
//!   `WeightCache` allocation (`Arc` pointer equality) — closing the
//!   ROADMAP open item on duplicated bind-time weight state.

use std::collections::HashMap;
use std::sync::Arc;

use clusterformer::clustering::{ClusterScheme, ClusteredTensors, Quantizer};
use clusterformer::runtime::interp::{pool, stats, InterpExecutor};
use clusterformer::runtime::ResidentExecutor as _;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::vit_shaped_hlo;

/// The process-wide counters are shared; serialize the tests in this
/// binary so their before/after reads don't race.
static COUNTER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// The clustered-matmul lowering at batch size `b`: codebook row slice +
/// u8 -> s32 convert -> gather -> dot -> bias add. Weight-subgraph
/// instruction names are identical across batch sizes, like the AOT
/// pipeline emits for one model.
fn clustered_hlo(b: usize) -> String {
    format!(
        "HloModule clustered_b{b}\n\
         ENTRY %main (x: f32[{b},6], cbs: f32[1,256], idx: u8[6,5], bias: f32[5]) -> (f32[{b},5]) {{\n  \
         %x = f32[{b},6]{{1,0}} parameter(0)\n  \
         %cbs = f32[1,256]{{1,0}} parameter(1)\n  \
         %idx = u8[6,5]{{1,0}} parameter(2)\n  \
         %bias = f32[5]{{0}} parameter(3)\n  \
         %sl = f32[1,256]{{1,0}} slice(%cbs), slice={{[0:1], [0:256]}}\n  \
         %row = f32[256]{{0}} reshape(%sl)\n  \
         %cvt = s32[6,5]{{1,0}} convert(%idx)\n  \
         %w = f32[6,5]{{1,0}} gather(%row, %cvt), offset_dims={{}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim=2, slice_sizes={{1}}\n  \
         %d = f32[{b},5]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n  \
         %bb = f32[{b},5]{{1,0}} broadcast(%bias), dimensions={{1}}\n  \
         %add = f32[{b},5]{{1,0}} add(%d, %bb)\n  \
         ROOT %t = (f32[{b},5]{{1,0}}) tuple(%add)\n}}\n"
    )
}

fn clustered_fixture() -> ClusteredTensors {
    let w: Vec<f32> = (0..30).map(|i| ((i as f32) * 0.47).sin()).collect();
    let dense = Tensor::from_f32(vec![6, 5], &w).unwrap();
    let names = vec!["w".to_string()];
    let mut tensors = HashMap::new();
    tensors.insert("w".to_string(), dense);
    Quantizer::new(8, ClusterScheme::PerLayer)
        .run(&names, &tensors)
        .unwrap()
}

fn fixed_inputs(ct: &ClusteredTensors) -> Arc<Vec<Tensor>> {
    Arc::new(vec![
        ct.codebooks.clone(),
        ct.indices["w"].clone(),
        Tensor::from_f32(vec![5], &[0.1, -0.2, 0.3, -0.4, 0.5]).unwrap(),
    ])
}

fn batch(b: usize, seed: f32) -> Tensor {
    let x: Vec<f32> = (0..b * 6).map(|i| ((i as f32) * seed).cos()).collect();
    Tensor::from_f32(vec![b, 6], &x).unwrap()
}

#[test]
fn steady_state_planned_execution_allocates_nothing() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let exe = InterpExecutor::load_text(&clustered_hlo(4), "zero-alloc").unwrap();
    let ct = clustered_fixture();
    let resident = exe.resident(1, fixed_inputs(&ct), Some(Arc::new(ct))).unwrap();
    assert!(
        resident.memory_plan().is_some(),
        "clustered module must be memory-planned"
    );
    let x = batch(4, 0.83);

    // Warmup: staging buffers and kernel scratch grow once here.
    let warm = resident.run(std::slice::from_ref(&x)).unwrap();

    let before = stats::tensor_allocs();
    for i in 0..5 {
        let out = resident.run(std::slice::from_ref(&x)).unwrap();
        assert_eq!(out[0], warm[0], "run {i} diverged");
    }
    assert_eq!(
        stats::tensor_allocs(),
        before,
        "steady-state planned execution must perform 0 tensor-path heap allocations"
    );
}

#[test]
fn planned_peak_is_under_half_of_unplanned_sum() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // The exact graph family the bench measures (shared fixture).
    let hlo = vit_shaped_hlo(16, 32, 4);
    let exe = InterpExecutor::load_text(&hlo, "vit-shaped-test").unwrap();
    let mem = exe.memory_plan().expect("ViT-shaped module must be plannable");
    assert!(
        mem.peak_bytes() * 2 <= mem.naive_bytes(),
        "planned peak {} must be <= 50% of unplanned sum {}",
        mem.peak_bytes(),
        mem.naive_bytes()
    );
}

#[test]
fn residents_at_different_batch_sizes_share_one_weight_cache() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ct = Arc::new(clustered_fixture());
    let fixed = fixed_inputs(&ct);

    let exe1 = InterpExecutor::load_text(&clustered_hlo(1), "pool-b1").unwrap();
    let exe8 = InterpExecutor::load_text(&clustered_hlo(8), "pool-b8").unwrap();
    let r1 = exe1.resident(1, fixed.clone(), Some(ct.clone())).unwrap();
    let r8 = exe8.resident(1, fixed.clone(), Some(ct.clone())).unwrap();

    // The caches carry real content (prepared packed weights), and the
    // two batch sizes hold the SAME allocation.
    let (c1, c8) = (r1.weight_cache(), r8.weight_cache());
    assert!(
        Arc::ptr_eq(&c1, &c8),
        "batch-1 and batch-8 residents must share one pooled WeightCache"
    );
    let (live_caches, live_packed) = pool::live_counts();
    assert!(live_caches >= 1, "pool must track the shared cache");
    assert!(live_packed >= 1, "pool must track the shared packed weight");

    // And both still compute correctly through the shared state.
    let x1 = batch(1, 0.83);
    let x8 = batch(8, 0.83);
    let o1 = r1.run(std::slice::from_ref(&x1)).unwrap();
    let o8 = r8.run(std::slice::from_ref(&x8)).unwrap();
    assert_eq!(o1[0].shape(), &[1, 5]);
    assert_eq!(o8[0].shape(), &[8, 5]);
    // Row 0 of the batch-8 run sees the same input as the batch-1 run.
    assert_eq!(
        o1[0].as_f32().unwrap(),
        o8[0].as_f32().unwrap()[..5].to_vec(),
        "shared weights must serve both batch sizes identically"
    );
}
