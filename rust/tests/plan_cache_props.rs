//! Acceptance properties for the dynamic-shape plan cache (ISSUE 7):
//!
//! * bucketed pad-to-bucket execution is **bit-for-bit** equal (modulo
//!   the sign of zero) to a fresh exact-shape bind, across dense and
//!   clustered/LUT weights, fused and unfused plans, and thread budgets
//!   1 and 4 — the length-masked attention fixtures make padded rows
//!   inert and the ascending-k GEMM accumulation makes trailing zero
//!   terms exact no-ops;
//! * cache hit/miss counters move exactly with the distinct buckets
//!   traffic touches, and warmed buckets never rebind;
//! * LRU eviction respects the capacity knob, drops the evicted plan
//!   (re-entry is a miss), and keeps pool-interned prepared weights
//!   shared across the eviction;
//! * KV-cached decode steps reproduce a from-scratch prefill over the
//!   full token prefix (<= 8 ulps — the step interleaves exact-zero
//!   empty-slot terms into the same accumulation order), with a
//!   logarithmic number of step-module binds.

use std::sync::Arc;

use clusterformer::runtime::interp::decode::{DecodeModel, DecodeSession};
use clusterformer::runtime::interp::plan_cache::{
    fingerprint64, plan_cache_from_env, BucketLadder, DynResident, ExecSource, PlanCache,
};
use clusterformer::runtime::interp::{stats, InterpExecutor};
use clusterformer::runtime::ThreadBudget;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::{
    decode_clustered, decode_clustered_inputs, decode_prefill_hlo, decode_step_hlo, decode_weights,
};
use clusterformer::util::rng::Pcg32;

/// The plan-cache counters are process-wide; serialize the tests in this
/// binary so their before/after reads don't race.
static COUNTER_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

const D: usize = 4;

fn scalar(v: usize) -> Tensor {
    Tensor::from_f32(vec![], &[v as f32]).unwrap()
}

fn random_tokens(n: usize, rng: &mut Pcg32) -> Tensor {
    let vals: Vec<f32> = (0..n * D).map(|_| rng.normal() as f32 * 0.3).collect();
    Tensor::from_f32(vec![n, D], &vals).unwrap()
}

/// Fixed weight inputs + clustered metadata for one decode-fixture
/// configuration (deterministic: every call sees the same weights).
fn decode_fixed(
    clustered: bool,
) -> (
    Arc<Vec<Tensor>>,
    Option<Arc<clusterformer::clustering::ClusteredTensors>>,
) {
    let mut rng = Pcg32::new(42);
    let dense = decode_weights(D, &mut rng);
    if clustered {
        let ct = Arc::new(decode_clustered(&dense, 16));
        (Arc::new(decode_clustered_inputs(&ct)), Some(ct))
    } else {
        (Arc::new(dense), None)
    }
}

fn prefill_exec(s: usize, clustered: bool, fuse: bool, threads: usize) -> InterpExecutor {
    InterpExecutor::load_text(
        &decode_prefill_hlo(s, D, clustered),
        &format!("props/prefill[{s}]"),
    )
    .unwrap()
    .with_threads(ThreadBudget::new(threads))
    .with_fusion(fuse)
}

/// Monotonic integer mapping of f32 (±0 coincide), for ulp distances.
fn f32_ord(x: f32) -> i64 {
    let i = x.to_bits() as i32 as i64;
    if i < 0 {
        (i32::MIN as i64) - i
    } else {
        i
    }
}

fn max_ulp_diff(a: &Tensor, b: &Tensor) -> u64 {
    let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(&b)
        .map(|(&x, &y)| (f32_ord(x) - f32_ord(y)).unsigned_abs())
        .max()
        .unwrap_or(0)
}

#[test]
fn bucketed_padded_run_matches_exact_shape_bind_bitwise() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ladder = BucketLadder::new(vec![4, 8, 16]);
    // Dense and clustered/LUT weights, fused and unfused plans, thread
    // budgets 1 and 4 — every combination must slice back the exact
    // bind's bits.
    for (clustered, fuse, threads) in [
        (false, true, 1),
        (false, false, 4),
        (true, false, 1),
        (true, true, 4),
    ] {
        let (fixed, clus) = decode_fixed(clustered);
        let source: ExecSource =
            Box::new(move |s| Ok(prefill_exec(s, clustered, fuse, threads)));
        let dyn_res = DynResident::new(
            &format!("props/bitwise-c{clustered}-f{fuse}-t{threads}"),
            ladder.clone(),
            2,
            fixed.clone(),
            clus.clone(),
            source,
        );
        let mut rng = Pcg32::new(1000 + threads as u64);
        let mut lens = vec![1, 3, 4, 5, 9, 16];
        lens.extend((0..4).map(|_| 1 + (rng.normal().abs() * 5.0) as usize % 16));
        for n in lens {
            let x = random_tokens(n, &mut rng);
            let got = dyn_res.run(&[x.clone(), scalar(n)]).unwrap();
            let exact = prefill_exec(n, clustered, fuse, threads)
                .resident(2, fixed.clone(), clus.clone())
                .unwrap()
                .run(&[x, scalar(n)])
                .unwrap();
            assert_eq!(got.len(), exact.len());
            for (i, (g, e)) in got.iter().zip(&exact).enumerate() {
                assert_eq!(g.shape(), e.shape(), "output {i} shape at n={n}");
                assert_eq!(
                    g.as_f32().unwrap(),
                    e.as_f32().unwrap(),
                    "output {i} must match the exact-shape bind bit-for-bit \
                     (n={n}, clustered={clustered}, fuse={fuse}, threads={threads})"
                );
            }
        }
    }
}

#[test]
fn hit_miss_counters_track_buckets_and_warm_buckets_never_rebind() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !plan_cache_from_env() {
        // `CLUSTERFORMER_PLAN_CACHE=0` lane: every lookup is a miss and
        // nothing is retained — just pin that shape.
        let (fixed, _) = decode_fixed(false);
        let source: ExecSource = Box::new(move |s| Ok(prefill_exec(s, false, true, 1)));
        let dyn_res = DynResident::new(
            "props/disabled",
            BucketLadder::new(vec![4, 8]),
            2,
            fixed,
            None,
            source,
        );
        let mut rng = Pcg32::new(2);
        let (h0, m0) = (stats::plan_cache_hits(), stats::plan_cache_misses());
        for n in [3, 3, 4] {
            dyn_res.run(&[random_tokens(n, &mut rng), scalar(n)]).unwrap();
        }
        assert_eq!(stats::plan_cache_hits(), h0, "disabled cache never hits");
        assert_eq!(stats::plan_cache_misses(), m0 + 3, "disabled cache always rebinds");
        assert_eq!(dyn_res.cache().len(), 0, "disabled cache retains nothing");
        return;
    }
    let (fixed, _) = decode_fixed(false);
    let source: ExecSource = Box::new(move |s| Ok(prefill_exec(s, false, true, 1)));
    let dyn_res = DynResident::new(
        "props/counters",
        BucketLadder::new(vec![4, 8]),
        2,
        fixed,
        None,
        source,
    );
    let mut rng = Pcg32::new(2);
    let h0 = stats::plan_cache_hits();
    let m0 = stats::plan_cache_misses();
    let e0 = stats::plan_cache_entries();
    // Lengths 3 and 4 share bucket 4; length 5 opens bucket 8; the
    // repeat at 3 is warm. Two buckets => exactly two misses.
    for n in [3, 4, 5, 3] {
        dyn_res.run(&[random_tokens(n, &mut rng), scalar(n)]).unwrap();
    }
    assert_eq!(
        stats::plan_cache_misses(),
        m0 + 2,
        "misses must equal the distinct buckets touched"
    );
    assert_eq!(stats::plan_cache_hits(), h0 + 2);
    assert_eq!(stats::plan_cache_entries(), e0 + 2, "entries gauge tracks bound plans");
    assert_eq!(dyn_res.cache().len(), 2);

    // Steady state: warmed buckets serve any shape-varying traffic with
    // zero rebinds.
    let m_warm = stats::plan_cache_misses();
    for n in [1, 2, 3, 4, 5, 6, 7, 8] {
        dyn_res.run(&[random_tokens(n, &mut rng), scalar(n)]).unwrap();
    }
    assert_eq!(
        stats::plan_cache_misses(),
        m_warm,
        "no rebinds after warmup"
    );

    // Dropping the resident releases its entries from the gauge.
    drop(dyn_res);
    assert_eq!(stats::plan_cache_entries(), e0);
}

#[test]
fn lru_eviction_respects_cap_and_keeps_pooled_weights() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if !plan_cache_from_env() {
        return; // nothing is retained, so nothing to evict
    }
    let (fixed, _) = decode_fixed(false);
    let fp = fingerprint64("props/evict");
    let cache = PlanCache::with_cap("props/evict", 2);
    let bind = |s: usize| {
        let exe = prefill_exec(s, false, true, 1);
        let sig = exe.parameter_dims().unwrap()[..2].to_vec();
        let fixed = fixed.clone();
        cache
            .get_or_bind(fp, &sig, move || exe.resident(2, fixed, None))
            .unwrap()
    };
    let m0 = stats::plan_cache_misses();
    let h0 = stats::plan_cache_hits();
    let kept_weights = bind(4).weight_cache();
    bind(8);
    assert_eq!(cache.len(), 2);
    bind(4); // refresh 4: LRU is now 8
    bind(16); // past cap: evicts 8
    assert_eq!(cache.len(), 2, "capacity bounds the cache");
    assert_eq!(stats::plan_cache_misses(), m0 + 3);
    assert_eq!(stats::plan_cache_hits(), h0 + 1);
    // Re-entering the evicted bucket is a miss (its plan is gone) ...
    bind(8);
    assert_eq!(stats::plan_cache_misses(), m0 + 4);
    assert_eq!(cache.len(), 2);
    // ... and bucket 4, evicted by that rebind, re-binds onto the SAME
    // pool-interned prepared weights: eviction drops plans and arenas,
    // never the shared weight state.
    let rebound = bind(4);
    assert!(
        Arc::ptr_eq(&kept_weights, &rebound.weight_cache()),
        "prepared weights must stay pool-shared across eviction"
    );
}

#[test]
fn kv_cached_decode_steps_match_from_scratch_prefill() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for clustered in [false, true] {
        let (fixed, clus) = decode_fixed(clustered);
        let model = DecodeModel {
            label: format!("props/decode-{}", if clustered { "lut" } else { "dense" }),
            dim: D,
            weights: fixed.clone(),
            clustered: clus.clone(),
            prefill_hlo: Box::new(move |s| decode_prefill_hlo(s, D, clustered)),
            step_hlo: Box::new(move |s| decode_step_hlo(s, D, clustered)),
            threads: ThreadBudget::new(1),
        };
        let mut session = DecodeSession::new(model, BucketLadder::new(vec![4, 8, 16, 32]));

        let mut rng = Pcg32::new(77);
        let tokens: Vec<Tensor> = (0..12).map(|_| random_tokens(1, &mut rng)).collect();
        let prompt_refs: Vec<&Tensor> = tokens[..5].iter().collect();
        let prompt = Tensor::concat_rows(&prompt_refs).unwrap();
        session.prefill(&prompt).unwrap();
        assert_eq!(session.len(), 5);

        for t in 5..tokens.len() {
            let y = session.step(&tokens[t]).unwrap();
            // Reference: a fresh exact-shape prefill over the whole
            // prefix, no cache, no padding.
            let n = t + 1;
            let prefix_refs: Vec<&Tensor> = tokens[..n].iter().collect();
            let prefix = Tensor::concat_rows(&prefix_refs).unwrap();
            let reference = prefill_exec(n, clustered, true, 1)
                .resident(2, fixed.clone(), clus.clone())
                .unwrap()
                .run(&[prefix, scalar(n)])
                .unwrap();
            let y_ref = reference[0].slice_rows(n - 1, n).unwrap();
            let ulps = max_ulp_diff(&y, &y_ref);
            assert!(
                ulps <= 8,
                "step {t} diverged from the from-scratch prefill by {ulps} ulps \
                 (clustered={clustered})"
            );
        }
        assert_eq!(session.len(), tokens.len());
        // 5-token prefill + 7 steps crosses buckets 8 -> 16 once: the
        // seed bind plus one migration. Binds stay logarithmic, never
        // per-token.
        assert_eq!(session.rebinds(), 2, "clustered={clustered}");
    }
}
