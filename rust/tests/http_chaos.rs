//! Network chaos tests for the HTTP/1.1 front end: status-code mapping
//! for every failure mode of the serving substrate, slowloris and
//! budget enforcement, injected network faults (`stall_read` /
//! `slow_write` / `reset`), connection backpressure on the accept
//! path, and graceful drain.
//!
//! The invariant: **every accepted request gets exactly one terminal
//! HTTP response, or a clean connection teardown** — never a hang,
//! never two responses, and the worker-side exactly-once accounting
//! still reconciles when the response path is torn.
//!
//! Fault rules are keyed by label process-wide, so each test uses its
//! own model name and its own HTTP label.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use clusterformer::coordinator::{
    faults, BatchPolicy, BatcherConfig, HttpConfig, HttpServer, ResilienceConfig, Server,
    ServerConfig,
};
use clusterformer::model::VariantKey;
use clusterformer::runtime::{BackendKind, ThreadBudget};
use clusterformer::testing::synthetic::{SyntheticServing, CLASSES};
use clusterformer::util::json::{self, Json};

fn start_server(synth: &SyntheticServing, resilience: ResilienceConfig) -> Server {
    Server::start(ServerConfig {
        artifacts_dir: synth.dir.clone(),
        targets: vec![(synth.model.clone(), VariantKey::Baseline)],
        backend: BackendKind::Interp,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            policy: BatchPolicy::Adaptive,
            queue_cap: 100_000,
        },
        threads: ThreadBudget::new(2),
        resilience,
    })
    .expect("synthetic server must start")
}

fn start_http(server: &Server, cfg: HttpConfig) -> HttpServer {
    HttpServer::start(server.router.clone(), server.metrics.clone(), cfg)
        .expect("http front end must start")
}

/// One-shot raw exchange: write `raw`, read until the server closes.
/// Returns the full response text (empty string = clean teardown with
/// no bytes, i.e. an injected reset or torn connection).
fn raw_roundtrip(addr: SocketAddr, raw: &[u8]) -> std::io::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    s.write_all(raw)?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    Ok(text)
}

fn parse_response(text: &str) -> (u16, String) {
    let status = text
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse::<u16>().ok())
        .unwrap_or(0);
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let text = raw_roundtrip(addr, raw.as_bytes()).expect("roundtrip");
    assert!(!text.is_empty(), "no response to {method} {path}");
    parse_response(&text)
}

fn classify_body(target: &str, image: &[f32], extra: &str) -> String {
    let vals: Vec<String> = image.iter().map(|v| format!("{v}")).collect();
    format!(
        "{{\"target\":\"{target}\",\"shape\":[2,2,3],\"image\":[{}]{extra}}}",
        vals.join(",")
    )
}

fn image_values(seed: u64) -> Vec<f32> {
    SyntheticServing::image(seed).as_f32().expect("synthetic image is f32")
}

/// Happy path plus the whole 4xx validation surface, exercised through
/// real sockets, with the counters reconciling at the end.
#[test]
fn routes_and_validation_map_to_typed_statuses() {
    let synth = SyntheticServing::build("httpok");
    let target = synth.baseline_target();
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: "httpok-fe".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    // Classification result matches the reference logits bit-for-bit
    // modulo the decimal round trip.
    let img = image_values(7);
    let (status, body) = request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""));
    assert_eq!(status, 200, "classify failed: {body}");
    let parsed = json::parse(&body).expect("response body is JSON");
    let logits = parsed.req_arr("logits").expect("logits present");
    let want = synth.reference_logits(&SyntheticServing::image(7));
    assert_eq!(logits.len(), CLASSES);
    for (got, want) in logits.iter().zip(&want) {
        let got = got.as_f64().expect("logit is a number");
        assert!((got - *want as f64).abs() < 1e-4, "logit {got} vs {want}");
    }

    let (status, body) = request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(body.contains(&target), "healthz lists targets: {body}");

    let (status, body) = request(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert!(body.contains("conns_accepted") && body.contains("variants"), "stats: {body}");

    let (status, _) = request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);

    let (status, body) =
        request(addr, "POST", "/v1/classify", &classify_body("no/such", &img, ""));
    assert_eq!(status, 404);
    assert!(body.contains("known"), "unknown-target reply lists known targets: {body}");

    let (status, body) = request(addr, "POST", "/v1/classify", "{\"target\": oops}");
    assert_eq!(status, 400);
    assert!(body.contains("offset"), "JSON errors carry a byte offset: {body}");

    let (status, body) = request(
        addr,
        "POST",
        "/v1/classify",
        &format!("{{\"target\":\"{target}\",\"shape\":[5],\"image\":[1,2,3]}}"),
    );
    assert_eq!(status, 400);
    assert!(body.contains("elements"), "shape mismatch is explained: {body}");

    // POST with no Content-Length is 411, not a hang.
    let text = raw_roundtrip(
        addr,
        b"POST /v1/classify HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    )
    .expect("roundtrip");
    assert_eq!(parse_response(&text).0, 411);

    let h = server.snapshot().http;
    assert!(h.http_2xx >= 3, "2xx counted: {h:?}");
    assert!(h.http_4xx >= 4, "4xx counted: {h:?}");
    assert_eq!(h.http_5xx, 0, "no 5xx in the happy-path test: {h:?}");

    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// A client that sends a drip of header bytes and then stalls is killed
/// with 408 once the read deadline lapses — the whole request must
/// arrive within `read_timeout` from its first byte.
#[test]
fn slowloris_is_killed_with_408() {
    let synth = SyntheticServing::build("httploris");
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig {
            label: "httploris-fe".to_string(),
            read_timeout: Duration::from_millis(150),
            idle_timeout: Duration::from_millis(400),
            ..HttpConfig::default()
        },
    );
    let mut s = TcpStream::connect(http.addr()).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    s.write_all(b"GET /healthz HTT").expect("partial header");
    // Stall past the read deadline without closing.
    let mut text = String::new();
    s.read_to_string(&mut text).expect("read 408");
    assert_eq!(parse_response(&text).0, 408, "slowloris reply: {text:?}");

    let h = server.snapshot().http;
    assert_eq!(h.slow_client_kills, 1, "{h:?}");

    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// Header and body budgets answer 413 instead of buffering without
/// bound.
#[test]
fn oversized_requests_get_413() {
    let synth = SyntheticServing::build("httpbig");
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig {
            label: "httpbig-fe".to_string(),
            max_header_bytes: 512,
            max_body_bytes: 256,
            ..HttpConfig::default()
        },
    );
    let addr = http.addr();

    // Declared body over budget: rejected from the Content-Length
    // header alone, before any body bytes are read.
    let text = raw_roundtrip(
        addr,
        b"POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: 100000\r\nConnection: close\r\n\r\n",
    )
    .expect("roundtrip");
    assert_eq!(parse_response(&text).0, 413);

    // Header section over budget.
    let raw = format!(
        "GET /healthz HTTP/1.1\r\nHost: t\r\nX-Pad: {}\r\nConnection: close\r\n\r\n",
        "a".repeat(2048)
    );
    let text = raw_roundtrip(addr, raw.as_bytes()).expect("roundtrip");
    assert_eq!(parse_response(&text).0, 413);

    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// A connection dropped mid-request costs nothing: the handler thread
/// unwinds, the registry entry is removed, and the next request on a
/// fresh connection is served normally.
#[test]
fn torn_request_leaves_server_healthy() {
    let synth = SyntheticServing::build("httptorn");
    let target = synth.baseline_target();
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: "httptorn-fe".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(b"POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: 50\r\n\r\n{\"tar")
            .expect("torn write");
        // Drop: the server sees EOF mid-body and unwinds quietly.
    }
    std::thread::sleep(Duration::from_millis(50));

    let img = image_values(3);
    let (status, _) = request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""));
    assert_eq!(status, 200);
    // Both connections have closed (or are about to); nothing leaked.
    let t0 = Instant::now();
    while server.snapshot().http.conns_open > 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "torn connection leaked");
        std::thread::sleep(Duration::from_millis(5));
    }

    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// The `max_conns` bound sheds on the accept path with 503 +
/// `Retry-After` — a connection beyond the bound never occupies a
/// handler thread.
#[test]
fn connection_cap_sheds_on_accept_path() {
    let synth = SyntheticServing::build("httpcap");
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: "httpcap-fe".to_string(), max_conns: 1, ..HttpConfig::default() },
    );
    let addr = http.addr();

    // Occupy the single slot with a keep-alive connection; reading the
    // response guarantees it is registered before the second connect.
    let mut held = TcpStream::connect(addr).expect("connect");
    held.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    held.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").expect("write");
    let mut buf = [0u8; 4096];
    let n = held.read(&mut buf).expect("healthz reply");
    assert!(std::str::from_utf8(&buf[..n]).unwrap_or("").starts_with("HTTP/1.1 200"));

    let text = raw_roundtrip(addr, b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").expect("second");
    let (status, _) = parse_response(&text);
    assert_eq!(status, 503, "over-cap connection is shed: {text:?}");
    assert!(text.contains("Retry-After"), "shed reply is retryable: {text:?}");

    let h = server.snapshot().http;
    assert_eq!(h.conns_rejected, 1, "{h:?}");
    assert_eq!(h.conns_open, 1, "{h:?}");

    drop(held);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// Admission-control shedding surfaces as 429: under a flood with a
/// tiny queue bound and a slow worker, every request gets exactly one
/// response and the mix is 200s plus 429s — nothing hangs, nothing
/// gets answered twice.
#[test]
fn admission_shedding_maps_to_429() {
    let synth = SyntheticServing::build("httpshed");
    let target = synth.baseline_target();
    faults::force_faults(&format!("slow:{target}:80ms"));
    let server = start_server(
        &synth,
        ResilienceConfig { queue_bound: 2, ..ResilienceConfig::default() },
    );
    let http = start_http(
        &server,
        HttpConfig { label: "httpshed-fe".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    let mut joins = Vec::new();
    for i in 0..16u64 {
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let img = image_values(i + 1);
            request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""))
        }));
    }
    let mut ok = 0usize;
    let mut shed = 0usize;
    for j in joins {
        let (status, body) = j.join().expect("client thread");
        match status {
            200 => ok += 1,
            429 => shed += 1,
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert_eq!(ok + shed, 16, "exactly one response per request");
    assert!(ok >= 1, "some requests complete under the flood");
    assert!(shed >= 1, "the tiny queue bound sheds under the flood");

    faults::clear_faults(&target);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// A request whose client deadline expires while the worker is busy
/// comes back 504 — the deadline propagated into `SubmitOptions` and
/// the batcher reaped it before dispatch.
#[test]
fn expired_deadline_maps_to_504() {
    let synth = SyntheticServing::build("httplate");
    let target = synth.baseline_target();
    faults::force_faults(&format!("slow:{target}:60ms"));
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: "httplate-fe".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    // Occupy the worker: a full batch dispatched and sleeping in the
    // slow-executor fault by the time the deadline request arrives.
    let router = server.router.clone();
    let mut occupy = Vec::new();
    for i in 0..4u64 {
        occupy.push(
            router
                .submit(&target, SyntheticServing::image(100 + i))
                .expect("occupying submit")
                .1,
        );
    }
    std::thread::sleep(Duration::from_millis(25));

    let img = image_values(9);
    let (status, body) = request(
        addr,
        "POST",
        "/v1/classify",
        &classify_body(&target, &img, ",\"deadline_ms\":1"),
    );
    assert_eq!(status, 504, "expired deadline: {body}");

    for rx in &occupy {
        let _ = rx.recv_timeout(Duration::from_secs(10));
    }
    faults::clear_faults(&target);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// A worker that dies with the request in flight answers 503 ("request
/// lost", retryable), and once the target is permanently dead, new
/// submissions answer 503 on the submit path — never a hung connection.
#[test]
fn dead_worker_maps_to_503() {
    let synth = SyntheticServing::build("httpdead");
    let target = synth.baseline_target();
    faults::force_faults(&format!("panic:{target}:1"));
    let server = start_server(
        &synth,
        ResilienceConfig { max_restarts: 0, ..ResilienceConfig::default() },
    );
    let http = start_http(
        &server,
        HttpConfig { label: "httpdead-fe".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    let img = image_values(5);
    let (status, body) =
        request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""));
    assert_eq!(status, 503, "in-flight loss is 503: {body}");

    // Restart budget is 0, so the target is now permanently dead.
    let handle = server.router.handle(&target).expect("target exists");
    let t0 = Instant::now();
    while handle.state() != clusterformer::coordinator::router::WorkerState::Dead {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never died");
        std::thread::sleep(Duration::from_millis(5));
    }
    let (status, body) =
        request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""));
    assert_eq!(status, 503, "dead target is 503 on submit: {body}");

    let h = server.snapshot().http;
    assert!(h.http_5xx >= 2, "{h:?}");

    faults::clear_faults(&target);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// An injected `reset` tears the connection cleanly where the response
/// would have been — the client sees EOF, not garbage — and the
/// worker-side accounting still shows every request executed exactly
/// once.
#[test]
fn injected_reset_is_a_clean_teardown() {
    let synth = SyntheticServing::build("httprst");
    let target = synth.baseline_target();
    let label = "httprst-fe";
    faults::force_faults(&format!("reset:{label}:2"));
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: label.to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    let mut texts = Vec::new();
    for i in 0..3u64 {
        let img = image_values(20 + i);
        let body = classify_body(&target, &img, "");
        let raw = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        texts.push(raw_roundtrip(addr, raw.as_bytes()).expect("roundtrip"));
    }
    assert_eq!(parse_response(&texts[0]).0, 200, "request 1 served: {:?}", texts[0]);
    assert!(texts[1].is_empty(), "request 2 sees a clean reset: {:?}", texts[1]);
    assert_eq!(parse_response(&texts[2]).0, 200, "request 3 served: {:?}", texts[2]);

    // All three executed server-side — the torn response did not lose
    // or duplicate work.
    let snap = server.snapshot();
    let v = snap.per_variant.get(&target).expect("variant stats");
    assert_eq!(v.requests, 3, "worker accounting reconciles");

    faults::clear_faults(label);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// `stall_read` and `slow_write` injectors add latency on the network
/// edge without corrupting anything: the request still completes with
/// a valid 200.
#[test]
fn stall_and_slow_write_injectors_add_latency() {
    let synth = SyntheticServing::build("httpstall");
    let target = synth.baseline_target();
    let label = "httpstall-fe";
    faults::force_faults(&format!("stall_read:{label}:50ms,slow_write:{label}:40ms"));
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: label.to_string(), ..HttpConfig::default() },
    );

    let img = image_values(11);
    let t0 = Instant::now();
    let (status, body) =
        request(http.addr(), "POST", "/v1/classify", &classify_body(&target, &img, ""));
    let elapsed = t0.elapsed();
    assert_eq!(status, 200, "{body}");
    assert!(
        elapsed >= Duration::from_millis(60),
        "injectors add latency (elapsed {elapsed:?})"
    );

    faults::clear_faults(label);
    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// Graceful drain: shutdown mid-flight stops accepting but flushes
/// every in-flight response — zero dropped requests — and afterwards
/// the port no longer accepts.
#[test]
fn graceful_drain_flushes_in_flight() {
    let synth = SyntheticServing::build("httpdrain");
    let target = synth.baseline_target();
    faults::force_faults(&format!("slow:{target}:50ms"));
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig {
            label: "httpdrain-fe".to_string(),
            drain: Duration::from_secs(10),
            ..HttpConfig::default()
        },
    );
    let addr = http.addr();

    let mut joins = Vec::new();
    for i in 0..4u64 {
        let target = target.clone();
        joins.push(std::thread::spawn(move || {
            let img = image_values(30 + i);
            request(addr, "POST", "/v1/classify", &classify_body(&target, &img, ""))
        }));
    }
    // Let the requests reach the (slow) worker, then drain under them.
    std::thread::sleep(Duration::from_millis(25));
    http.shutdown();

    for j in joins {
        let (status, body) = j.join().expect("client thread");
        assert_eq!(status, 200, "in-flight request flushed during drain: {body}");
    }
    let h = server.snapshot().http;
    assert!(h.drain_flushed >= 1, "responses written during drain are counted: {h:?}");
    assert_eq!(h.conns_open, 0, "drain leaves no connection open: {h:?}");

    // The listener is gone: new connections are refused (or at best
    // connect to nothing that answers).
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(mut s) => {
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
            let mut text = String::new();
            let n = s.read_to_string(&mut text).unwrap_or(0);
            assert_eq!(n, 0, "no server behind the drained port: {text:?}");
        }
    }

    faults::clear_faults(&target);
    server.shutdown();
    synth.cleanup();
}

/// Runs only under `CLUSTERFORMER_FAULTS` mentioning the `envhttp`
/// label (the CI chaos step): with env-injected network faults live,
/// every request still gets exactly one response or one clean reset,
/// and the worker-side accounting reconciles.
#[test]
fn env_gated_network_faults_reconcile() {
    let Some(spec) = faults::env_spec() else { return };
    if !spec.contains("envhttp") {
        return;
    }
    let synth = SyntheticServing::build("envhttpm");
    let target = synth.baseline_target();
    let server = start_server(&synth, ResilienceConfig::default());
    let http = start_http(
        &server,
        HttpConfig { label: "envhttp".to_string(), ..HttpConfig::default() },
    );
    let addr = http.addr();

    const N: u64 = 6;
    let mut answered = 0u64;
    let mut resets = 0u64;
    for i in 0..N {
        let img = image_values(40 + i);
        let body = classify_body(&target, &img, "");
        let raw = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let text = raw_roundtrip(addr, raw.as_bytes()).expect("roundtrip");
        if text.is_empty() {
            resets += 1;
        } else {
            assert_eq!(parse_response(&text).0, 200, "served under env faults: {text:?}");
            answered += 1;
        }
    }
    assert_eq!(answered + resets, N, "one terminal outcome per request");
    if spec.contains("reset:envhttp") {
        assert!(resets >= 1, "the env reset injector fired");
    }
    let snap = server.snapshot();
    let v = snap.per_variant.get(&target).expect("variant stats");
    assert_eq!(v.requests, N, "accounting reconciles under env faults");

    http.shutdown();
    server.shutdown();
    synth.cleanup();
}

/// Sanity for the helpers themselves: `Json::obj` bodies we assert
/// against really are compact JSON.
#[test]
fn helper_bodies_are_json() {
    let j = Json::obj(vec![("error", Json::Str("x".to_string()))]);
    assert_eq!(j.to_string_compact(), "{\"error\":\"x\"}");
}
