//! Acceptance tests for the bind-time plan verifier and the arena
//! sanitizer (ISSUE 9):
//!
//! * valid plans — hand-written and the ViT-shaped fixture — verify
//!   clean (zero diagnostics);
//! * each planted corruption (double-booked slot, live in-place donor,
//!   alias cycle, forward operand edge, persistent-parameter mutation,
//!   eliminated root element, out-of-range slot) is rejected with the
//!   *right* rule id, via the `#[doc(hidden)]` corruption hooks on
//!   [`MemoryPlan`];
//! * a deliberate out-of-bounds write past a slot's planned capacity is
//!   caught by the arena canary on the next execution, attributed to the
//!   faulting run rather than surfacing as corruption downstream;
//! * the `verify_rules_checked` counter advances on every verified bind.

use std::sync::Arc;

use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::verify::{self, RuleId};
use clusterformer::runtime::interp::{stats, testing_build_plan, InterpExecutor, MemoryPlan};
use clusterformer::runtime::ResidentExecutor as _;
use clusterformer::tensor::Tensor;
use clusterformer::testing::fixtures::vit_shaped_hlo;

/// Four-instruction diamond over two parameters. Every intermediate has
/// at least two consumers (the root tuple pins %b and %c), so plan-time
/// fusion cannot collapse the chain and DCE keeps every node — the
/// instruction indices below are stable:
///
/// ```text
///   0 %x  param(0)        3 %b = multiply(%a, %x)
///   1 %y  param(1)        4 %c = subtract(%b, %a)
///   2 %a = add(%x, %y)    5 %r = reshape(%c)   (zero-copy alias)
///                         6 %t = tuple(%b, %c, %r)   ROOT
/// ```
const FIXTURE: &str = "HloModule verify_fixture\n\
    ENTRY %main (x: f32[4,4], y: f32[4,4]) -> (f32[4,4], f32[4,4], f32[16]) {\n  \
    %x = f32[4,4]{1,0} parameter(0)\n  \
    %y = f32[4,4]{1,0} parameter(1)\n  \
    %a = f32[4,4]{1,0} add(%x, %y)\n  \
    %b = f32[4,4]{1,0} multiply(%a, %x)\n  \
    %c = f32[4,4]{1,0} subtract(%b, %a)\n  \
    %r = f32[16]{0} reshape(%c)\n  \
    ROOT %t = (f32[4,4]{1,0}, f32[4,4]{1,0}, f32[16]{0}) tuple(%b, %c, %r)\n}\n";

const A: usize = 2;
const B: usize = 3;
const C: usize = 4;
const R: usize = 5;
const ROOT: usize = 6;

fn fixture_plan() -> (HloModule, MemoryPlan) {
    let module = HloModule::parse(FIXTURE).expect("fixture parses");
    let plan = testing_build_plan(&module).expect("fixture binds");
    (module, plan)
}

fn rules_of(module: &HloModule, plan: &MemoryPlan) -> Vec<&'static str> {
    verify::verify_module_plan(module, plan)
        .expect("verifier runs")
        .into_iter()
        .map(|d| d.rule.id())
        .collect()
}

#[test]
fn valid_plans_verify_clean() {
    let (module, plan) = fixture_plan();
    assert_eq!(
        plan.testing_compute_indices(),
        vec![A, B, C],
        "fixture lowers %a/%b/%c as computes"
    );
    assert_eq!(plan.testing_alias_indices(), vec![R], "reshape is a zero-copy alias");
    let diags = verify::verify_module_plan(&module, &plan).expect("verifier runs");
    assert!(diags.is_empty(), "valid fixture plan must verify clean: {diags:?}");

    let vit = HloModule::parse(&vit_shaped_hlo(16, 32, 4)).expect("vit fixture parses");
    let vplan = testing_build_plan(&vit).expect("vit fixture binds");
    let vdiags = verify::verify_module_plan(&vit, &vplan).expect("verifier runs");
    assert!(vdiags.is_empty(), "ViT-shaped plan must verify clean: {vdiags:?}");
}

#[test]
fn out_of_range_slot_is_rejected() {
    let (module, mut plan) = fixture_plan();
    plan.testing_set_slot(A, 9999);
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::SlotCompat.id()),
        "out-of-range slot must trip slot-compat, got {rules:?}"
    );
}

#[test]
fn double_booked_slot_is_rejected() {
    let (module, mut plan) = fixture_plan();
    // %c steals %b's slot: %b is re-read by the root tuple *after* %c
    // executes, so the replay sees the root read a slot that now holds
    // %c's value.
    let b_slot = plan.testing_slot_of(B).expect("%b is a compute");
    assert_ne!(
        plan.testing_slot_of(C),
        Some(b_slot),
        "fixture keeps %b and %c in distinct slots (both live to the end)"
    );
    plan.testing_set_slot(C, b_slot);
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::SlotReplay.id()),
        "double-booked slot must trip slot-replay, got {rules:?}"
    );
}

#[test]
fn inplace_over_live_operand_is_rejected() {
    let (module, mut plan) = fixture_plan();
    // %b claims its operand %a as an in-place donor, but %a is still
    // read by %c afterwards.
    plan.testing_set_inplace(B, Some(0));
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::InplaceLegal.id()),
        "in-place over a live donor must trip inplace-legal, got {rules:?}"
    );
}

#[test]
fn alias_cycle_is_rejected() {
    let (module, mut plan) = fixture_plan();
    // The reshape alias now points at itself: its chain never resolves.
    plan.testing_redirect_operand(R, 0, R);
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::AliasChain.id()),
        "cyclic alias chain must trip alias-chain, got {rules:?}"
    );
}

#[test]
fn forward_operand_edge_is_rejected() {
    let (module, mut plan) = fixture_plan();
    // %c's first operand points forward at the root tuple.
    plan.testing_redirect_operand(C, 0, ROOT);
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::DefBeforeUse.id()),
        "forward operand edge must trip def-before-use, got {rules:?}"
    );
}

#[test]
fn inplace_mutation_of_persistent_param_is_rejected() {
    let (module, mut plan) = fixture_plan();
    // Parameter 0 becomes persistent cross-call state (the KV-cache
    // class); %a then claims it as an in-place donor — previous calls'
    // state would be clobbered.
    plan.testing_set_persistent(0, true);
    plan.testing_set_inplace(A, Some(0));
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::PersistentIsolation.id()),
        "mutating a persistent parameter must trip persistent-isolation, got {rules:?}"
    );
}

#[test]
fn eliminated_root_element_is_rejected() {
    let (module, mut plan) = fixture_plan();
    plan.testing_skip(C);
    let rules = rules_of(&module, &plan);
    assert!(
        rules.contains(&RuleId::RootReachable.id()),
        "skipping a root tuple element must trip root-reachable, got {rules:?}"
    );
}

#[test]
fn verified_bind_advances_rule_counter() {
    let before = stats::verify_rules_checked();
    let (_module, _plan) = fixture_plan();
    let after = stats::verify_rules_checked();
    assert!(
        after >= before + verify::RULE_COUNT,
        "bind must verify all {} rules (counter {before} -> {after})",
        verify::RULE_COUNT
    );
}

#[test]
fn arena_canary_catches_out_of_bounds_write() {
    // The sanitizer defaults to on in debug builds only; force it on so
    // this test also bites under `cargo test --release`. The env var is
    // resolved once per process, and this integration-test binary is its
    // own process, so setting it before the first bind is reliable.
    std::env::set_var("CLUSTERFORMER_SANITIZE", "1");

    let exe = InterpExecutor::load_text(FIXTURE, "canary").expect("fixture loads");
    let resident = exe.resident(2, Arc::new(Vec::new()), None).expect("fixture binds");
    assert!(resident.memory_plan().is_some(), "fixture must be memory-planned");

    let x = Tensor::from_f32(vec![4, 4], &[0.5; 16]).expect("input");
    let y = Tensor::from_f32(vec![4, 4], &[-0.25; 16]).expect("input");
    resident.run(&[x.clone(), y.clone()]).expect("clean run succeeds");

    // One element written past slot 0's planned capacity — the kind of
    // off-by-one an unsafe GEMM/LUT kernel produces.
    resident.testing_smash_canary().expect("sanitizer is active");
    let err = resident
        .run(&[x, y])
        .expect_err("run over a smashed canary must fail");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("canary"),
        "sanitizer error must name the canary, got: {msg}"
    );
}
