//! Property tests for plan-time operator fusion (ISSUE 5):
//!
//! * fused elementwise chains — including folded scalar / bias-row /
//!   normalizer-column broadcasts — are **bit-for-bit** equal to the
//!   classic per-kernel evaluator on randomized graphs, at thread
//!   budgets 1/2/4;
//! * GEMM and clustered-LUT epilogues are bit-for-bit equal too
//!   (full-input and weight-resident), including problems large enough
//!   to really fan out on the kernel pool;
//! * the fused online softmax — the one lowering that is *not*
//!   bit-identical by construction — stays within **4 ULP** of the
//!   classic reduce/exp/divide chain elementwise, is bit-identical
//!   across thread budgets, and the `--no-fusion` path stays bitwise
//!   equal to the classic evaluator;
//! * non-f32 elementwise chains are left unfused and stay correct.

use std::collections::HashMap;
use std::sync::Arc;

use clusterformer::clustering::{ClusterScheme, Quantizer};
use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::{evaluate_unplanned, InterpExecutor};
use clusterformer::runtime::{Executor as _, ResidentExecutor as _, ThreadBudget};
use clusterformer::tensor::Tensor;
use clusterformer::testing::prop::{check, ulp_dist, Gen};
use clusterformer::util::rng::Pcg32;

fn rand_tensor(g: &mut Gen, dims: &[usize], scale: f32) -> Tensor {
    let n: usize = dims.iter().product();
    let vals: Vec<f32> = (0..n).map(|_| g.f32_normal() * scale).collect();
    Tensor::from_f32(dims.to_vec(), &vals).unwrap()
}

/// Random elementwise-chain module over `[m, n]`: every step consumes
/// the previous value exactly once; second operands rotate through a
/// scalar constant, a bias-row broadcast (`dims={1}` of `bias[n]`), a
/// normalizer-column broadcast (`dims={0}` of `col[m]`), and the live
/// full-size tensor `x1` — every FusedIn mode.
fn chain_hlo(g: &mut Gen, m: usize, n: usize, steps: usize) -> String {
    let mn = format!("f32[{m},{n}]{{1,0}}");
    let mut body = String::new();
    let mut cur = "x0".to_string();
    for s in 0..steps {
        let y = format!("s{s}");
        match g.usize(0, 4) {
            0 => {
                let op = *g.pick(&["exponential", "tanh", "negate", "abs", "erf", "logistic"]);
                body.push_str(&format!("  %{y} = {mn} {op}(%{cur})\n"));
            }
            1 => {
                let op = *g.pick(&["add", "subtract", "multiply", "maximum"]);
                let v = *g.pick(&["0.5", "1.5", "-2"]);
                body.push_str(&format!("  %k{s} = f32[] constant({v})\n"));
                if g.bool() {
                    body.push_str(&format!("  %{y} = {mn} {op}(%{cur}, %k{s})\n"));
                } else {
                    body.push_str(&format!("  %{y} = {mn} {op}(%k{s}, %{cur})\n"));
                }
            }
            2 => {
                let op = *g.pick(&["add", "subtract", "multiply", "maximum"]);
                body.push_str(&format!(
                    "  %g{s} = {mn} broadcast(%bias), dimensions={{1}}\n"
                ));
                if g.bool() {
                    body.push_str(&format!("  %{y} = {mn} {op}(%{cur}, %g{s})\n"));
                } else {
                    body.push_str(&format!("  %{y} = {mn} {op}(%g{s}, %{cur})\n"));
                }
            }
            3 => {
                let op = *g.pick(&["add", "multiply", "maximum"]);
                body.push_str(&format!(
                    "  %g{s} = {mn} broadcast(%col), dimensions={{0}}\n"
                ));
                body.push_str(&format!("  %{y} = {mn} {op}(%{cur}, %g{s})\n"));
            }
            _ => {
                let op = *g.pick(&["add", "subtract", "multiply", "maximum"]);
                body.push_str(&format!("  %{y} = {mn} {op}(%{cur}, %x1)\n"));
            }
        }
        cur = y;
    }
    body.push_str(&format!("  ROOT %out = {mn} negate(%{cur})\n"));
    format!(
        "HloModule chain_prop\n\
         ENTRY %e (x0: f32[{m},{n}], x1: f32[{m},{n}], bias: f32[{n}], col: f32[{m}]) -> f32[{m},{n}] {{\n\
         \x20 %x0 = f32[{m},{n}]{{1,0}} parameter(0)\n\
         \x20 %x1 = f32[{m},{n}]{{1,0}} parameter(1)\n\
         \x20 %bias = f32[{n}]{{0}} parameter(2)\n\
         \x20 %col = f32[{m}]{{0}} parameter(3)\n\
         {body}}}\n"
    )
}

#[test]
fn prop_fused_chains_match_classic_bitwise() {
    check("fused chains == classic (bitwise)", 40, |g| {
        let m = g.usize(2, 6);
        let n = g.usize(2, 6);
        let steps = g.usize(2, 5);
        let hlo = chain_hlo(g, m, n, steps);
        let inputs = vec![
            rand_tensor(g, &[m, n], 0.7),
            rand_tensor(g, &[m, n], 0.7),
            rand_tensor(g, &[n], 0.5),
            rand_tensor(g, &[m], 0.5),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let module = HloModule::parse(&hlo).unwrap();
        let classic = evaluate_unplanned(&module, &refs).unwrap();
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "chain-prop")
                .unwrap_or_else(|e| panic!("load failed: {e:#}\n{hlo}"))
                .with_threads(ThreadBudget::new(budget))
                .with_fusion(true);
            let mem = exe.memory_plan().unwrap_or_else(|| panic!("must plan\n{hlo}"));
            assert!(
                mem.fused_chains() >= 1,
                "a {steps}-step chain must fuse\n{hlo}"
            );
            let fused = exe.run(&inputs).unwrap_or_else(|e| panic!("run: {e:#}\n{hlo}"));
            assert_eq!(fused, classic, "fused chain diverged (budget {budget})\n{hlo}");
        }
        // Knob off: no fusion recorded, still bitwise equal.
        let exe = InterpExecutor::load_text(&hlo, "chain-prop-off")
            .unwrap()
            .with_fusion(false);
        let mem = exe.memory_plan().unwrap();
        assert_eq!(mem.fused_chains() + mem.fused_epilogues() + mem.fused_softmax(), 0);
        assert_eq!(exe.run(&inputs).unwrap(), classic, "unfused plan diverged\n{hlo}");
    });
}

fn gemm_epilogue_hlo(m: usize, k: usize, n: usize, act: &str) -> String {
    format!(
        "HloModule gemm_ep\n\
         ENTRY %e (x: f32[{m},{k}], w: f32[{k},{n}], bias: f32[{n}], res: f32[{m},{n}]) -> f32[{m},{n}] {{\n\
         \x20 %x = f32[{m},{k}]{{1,0}} parameter(0)\n\
         \x20 %w = f32[{k},{n}]{{1,0}} parameter(1)\n\
         \x20 %bias = f32[{n}]{{0}} parameter(2)\n\
         \x20 %res = f32[{m},{n}]{{1,0}} parameter(3)\n\
         \x20 %d = f32[{m},{n}]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 %bb = f32[{m},{n}]{{1,0}} broadcast(%bias), dimensions={{1}}\n\
         \x20 %s = f32[{m},{n}]{{1,0}} add(%d, %bb)\n\
         \x20 %a = f32[{m},{n}]{{1,0}} {act}(%s)\n\
         \x20 ROOT %o = f32[{m},{n}]{{1,0}} add(%res, %a)\n}}\n"
    )
}

#[test]
fn prop_gemm_epilogue_matches_classic_bitwise() {
    check("gemm epilogue == classic (bitwise)", 25, |g| {
        let m = g.usize(1, 7);
        let k = g.usize(1, 7);
        let n = g.usize(1, 7);
        let act = *g.pick(&["tanh", "erf", "exponential", "abs"]);
        let hlo = gemm_epilogue_hlo(m, k, n, act);
        let inputs = vec![
            rand_tensor(g, &[m, k], 0.8),
            rand_tensor(g, &[k, n], 0.4),
            rand_tensor(g, &[n], 0.5),
            rand_tensor(g, &[m, n], 0.7),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let module = HloModule::parse(&hlo).unwrap();
        let classic = evaluate_unplanned(&module, &refs).unwrap();
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "gemm-ep-prop")
                .unwrap()
                .with_threads(ThreadBudget::new(budget))
                .with_fusion(true);
            let mem = exe.memory_plan().expect("must plan");
            assert_eq!(mem.fused_epilogues(), 1, "dot must carry the epilogue\n{hlo}");
            let fused = exe.run(&inputs).unwrap();
            assert_eq!(fused, classic, "epilogue diverged (budget {budget})\n{hlo}");
        }
    });
}

#[test]
fn large_gemm_epilogue_fans_out_bit_identically() {
    // 2*96*96*96 flops > the GEMM parallel threshold, so budgets > 1
    // really hit the pool; the chunk-local epilogue must stay bitwise
    // equal to both the serial fused run and the classic chain.
    let (m, k, n) = (96usize, 96, 96);
    let hlo = gemm_epilogue_hlo(m, k, n, "tanh");
    let mut rng = Pcg32::new(2106);
    let mk: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
    let kn: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.3).collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.2).collect();
    let res: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.4).collect();
    let inputs = vec![
        Tensor::from_f32(vec![m, k], &mk).unwrap(),
        Tensor::from_f32(vec![k, n], &kn).unwrap(),
        Tensor::from_f32(vec![n], &bias).unwrap(),
        Tensor::from_f32(vec![m, n], &res).unwrap(),
    ];
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let module = HloModule::parse(&hlo).unwrap();
    let classic = evaluate_unplanned(&module, &refs).unwrap();
    for budget in [1usize, 2, 4] {
        let exe = InterpExecutor::load_text(&hlo, "gemm-ep-large")
            .unwrap()
            .with_threads(ThreadBudget::new(budget))
            .with_fusion(true);
        assert_eq!(exe.memory_plan().expect("must plan").fused_epilogues(), 1);
        assert_eq!(exe.run(&inputs).unwrap(), classic, "budget {budget} diverged");
    }
}

#[test]
fn prop_clustered_epilogue_matches_classic_bitwise() {
    check("clustered LUT epilogue == classic", 20, |g| {
        let m = g.usize(1, 5);
        let k = g.usize(2, 7);
        let n = g.usize(1, 6);
        let clusters = *g.pick(&[4usize, 8, 16]);
        let hlo = format!(
            "HloModule clustered_ep_prop\n\
             ENTRY %main (x: f32[{m},{k}], cbs: f32[1,256], idx: u8[{k},{n}], bias: f32[{n}]) -> (f32[{m},{n}]) {{\n  \
             %x = f32[{m},{k}]{{1,0}} parameter(0)\n  \
             %cbs = f32[1,256]{{1,0}} parameter(1)\n  \
             %idx = u8[{k},{n}]{{1,0}} parameter(2)\n  \
             %bias = f32[{n}]{{0}} parameter(3)\n  \
             %sl = f32[1,256]{{1,0}} slice(%cbs), slice={{[0:1], [0:256]}}\n  \
             %row = f32[256]{{0}} reshape(%sl)\n  \
             %cvt = s32[{k},{n}]{{1,0}} convert(%idx)\n  \
             %w = f32[{k},{n}]{{1,0}} gather(%row, %cvt), offset_dims={{}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim=2, slice_sizes={{1}}\n  \
             %d = f32[{m},{n}]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n  \
             %bb = f32[{m},{n}]{{1,0}} broadcast(%bias), dimensions={{1}}\n  \
             %s = f32[{m},{n}]{{1,0}} add(%d, %bb)\n  \
             %a = f32[{m},{n}]{{1,0}} tanh(%s)\n  \
             ROOT %t = (f32[{m},{n}]{{1,0}}) tuple(%a)\n}}\n"
        );
        let mut rng = Pcg32::new(g.u64());
        let wvals: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let dense = Tensor::from_f32(vec![k, n], &wvals).unwrap();
        let names = vec!["w".to_string()];
        let mut tensors = HashMap::new();
        tensors.insert("w".to_string(), dense);
        let ct = Quantizer::new(clusters, ClusterScheme::PerLayer)
            .run(&names, &tensors)
            .unwrap();
        let x = rand_tensor(g, &[m, k], 0.8);
        let bias = rand_tensor(g, &[n], 0.5);
        let inputs = vec![
            x.clone(),
            ct.codebooks.clone(),
            ct.indices["w"].clone(),
            bias.clone(),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let module = HloModule::parse(&hlo).unwrap();
        let classic = evaluate_unplanned(&module, &refs).unwrap();
        let ct = Arc::new(ct);
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "clustered-ep-prop")
                .unwrap()
                .with_threads(ThreadBudget::new(budget))
                .with_fusion(true);
            let mem = exe.memory_plan().expect("must plan");
            assert_eq!(mem.fused_epilogues(), 1, "LUT dot must carry the epilogue");
            assert_eq!(
                exe.run(&inputs).unwrap(),
                classic,
                "full-input clustered epilogue diverged (budget {budget})"
            );
            // Weight-resident: prepared (bit-packed) weights + epilogue.
            let resident = exe
                .resident(
                    1,
                    Arc::new(vec![ct.codebooks.clone(), ct.indices["w"].clone(), bias.clone()]),
                    Some(ct.clone()),
                )
                .unwrap();
            assert_eq!(
                resident.run(std::slice::from_ref(&x)).unwrap(),
                classic,
                "resident clustered epilogue diverged (budget {budget})"
            );
        }
    });
}

fn softmax_hlo(r: usize, c: usize) -> String {
    format!(
        "HloModule sm\n\
         %max_f (p0: f32[], p1: f32[]) -> f32[] {{\n  \
         %p0 = f32[] parameter(0)\n  \
         %p1 = f32[] parameter(1)\n  \
         ROOT %r = f32[] maximum(%p0, %p1)\n}}\n\
         %add_f (q0: f32[], q1: f32[]) -> f32[] {{\n  \
         %q0 = f32[] parameter(0)\n  \
         %q1 = f32[] parameter(1)\n  \
         ROOT %r2 = f32[] add(%q0, %q1)\n}}\n\
         ENTRY %e (a: f32[{r},{c}]) -> f32[{r},{c}] {{\n  \
         %a = f32[{r},{c}]{{1,0}} parameter(0)\n  \
         %ninf = f32[] constant(-inf)\n  \
         %mx = f32[{r}]{{0}} reduce(%a, %ninf), dimensions={{1}}, to_apply=%max_f\n  \
         %mxb = f32[{r},{c}]{{1,0}} broadcast(%mx), dimensions={{0}}\n  \
         %cs = f32[{r},{c}]{{1,0}} subtract(%a, %mxb)\n  \
         %x = f32[{r},{c}]{{1,0}} exponential(%cs)\n  \
         %zero = f32[] constant(0)\n  \
         %sm = f32[{r}]{{0}} reduce(%x, %zero), dimensions={{1}}, to_apply=%add_f\n  \
         %smb = f32[{r},{c}]{{1,0}} broadcast(%sm), dimensions={{0}}\n  \
         ROOT %o = f32[{r},{c}]{{1,0}} divide(%x, %smb)\n}}\n"
    )
}

#[test]
fn prop_fused_softmax_within_4_ulp_of_classic() {
    check("fused softmax <= 4 ULP of classic", 30, |g| {
        let r = g.usize(1, 8);
        let c = g.usize(2, 16);
        let hlo = softmax_hlo(r, c);
        // Logit-scaled inputs (attention scores live in this range; huge
        // spreads would stress the exp ULP budget without adding
        // coverage — the running max still moves several times per row).
        let a = rand_tensor(g, &[r, c], 1.5);
        let module = HloModule::parse(&hlo).unwrap();
        let classic = evaluate_unplanned(&module, &[&a]).unwrap();
        let cv = classic[0].as_f32().unwrap();
        let mut per_budget: Vec<Vec<f32>> = Vec::new();
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "softmax-prop")
                .unwrap()
                .with_threads(ThreadBudget::new(budget))
                .with_fusion(true);
            let mem = exe.memory_plan().expect("must plan");
            assert_eq!(mem.fused_softmax(), 1, "idiom must lower to the fused kernel");
            let out = exe.run(std::slice::from_ref(&a)).unwrap();
            let ov = out[0].as_f32().unwrap();
            for (i, (f, cl)) in ov.iter().zip(&cv).enumerate() {
                let d = ulp_dist(*f, *cl);
                assert!(
                    d <= 4,
                    "element {i}: fused {f} vs classic {cl} is {d} ULP apart (budget {budget})"
                );
            }
            per_budget.push(ov);
        }
        // Row-independent kernel: identical bits at every budget.
        assert_eq!(per_budget[0], per_budget[1]);
        assert_eq!(per_budget[0], per_budget[2]);
        // Knob off: bitwise equal to the classic evaluator.
        let exe = InterpExecutor::load_text(&hlo, "softmax-off")
            .unwrap()
            .with_fusion(false);
        assert_eq!(exe.memory_plan().unwrap().fused_softmax(), 0);
        assert_eq!(exe.run(std::slice::from_ref(&a)).unwrap(), classic);
    });
}

#[test]
fn large_fused_softmax_fans_out_bit_identically() {
    // 64 x 1024 clears the elementwise parallel threshold, so budgets
    // > 1 fan rows out on the pool; rows are lane-independent, so the
    // fused result must be bit-identical across budgets and still
    // within 4 ULP of the classic chain.
    let (r, c) = (64usize, 1024);
    let hlo = softmax_hlo(r, c);
    let mut rng = Pcg32::new(31 * 5);
    let av: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
    let a = Tensor::from_f32(vec![r, c], &av).unwrap();
    let module = HloModule::parse(&hlo).unwrap();
    let classic = evaluate_unplanned(&module, &[&a]).unwrap();
    let cv = classic[0].as_f32().unwrap();
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for budget in [1usize, 2, 4] {
        let exe = InterpExecutor::load_text(&hlo, "softmax-large")
            .unwrap()
            .with_threads(ThreadBudget::new(budget))
            .with_fusion(true);
        let out = exe.run(std::slice::from_ref(&a)).unwrap();
        let ov = out[0].as_f32().unwrap();
        for (f, cl) in ov.iter().zip(&cv) {
            assert!(ulp_dist(*f, *cl) <= 4, "{f} vs {cl} (budget {budget})");
        }
        outs.push(ov);
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0], outs[2]);
}

#[test]
fn non_f32_chains_are_left_unfused() {
    let hlo = "HloModule ints\n\
        ENTRY %e (x: s32[8], y: s32[8]) -> s32[8] {\n  \
        %x = s32[8]{0} parameter(0)\n  \
        %y = s32[8]{0} parameter(1)\n  \
        %a = s32[8]{0} add(%x, %y)\n  \
        %b = s32[8]{0} multiply(%a, %y)\n  \
        ROOT %c = s32[8]{0} maximum(%b, %x)\n}\n";
    let x = Tensor::from_i32(vec![8], &[1, -2, 3, -4, 5, -6, 7, -8]).unwrap();
    let y = Tensor::from_i32(vec![8], &[10, 20, -30, 40, -50, 60, -70, 80]).unwrap();
    let module = HloModule::parse(hlo).unwrap();
    let classic = evaluate_unplanned(&module, &[&x, &y]).unwrap();
    let exe = InterpExecutor::load_text(hlo, "int-chain").unwrap().with_fusion(true);
    let mem = exe.memory_plan().expect("must plan");
    assert_eq!(
        mem.fused_chains() + mem.fused_epilogues() + mem.fused_softmax(),
        0,
        "integer chains must stay on the per-kernel path"
    );
    assert_eq!(exe.run(&[x, y]).unwrap(), classic);
}
