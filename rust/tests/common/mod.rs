//! Shared helpers for artifact-dependent integration tests.

/// True when the AOT artifacts are present. When they are not (a fresh
/// clone, a CI box without the Python build step), prints a visible
/// skip notice and lets the caller return early instead of panicking —
/// `cargo test` must stay green without artifacts.
pub fn artifacts_available(test: &str) -> bool {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!(
            "SKIPPED {test}: artifacts/manifest.json not found \
             (run `make artifacts` to build the AOT artifacts)"
        );
        false
    }
}
