//! Property tests for the SIMD dispatch layer (ISSUE 6): every vector
//! microkernel is checked against the scalar reference **at every
//! dispatch level the host supports**, by forcing the level through
//! `force_kernel_isa` and re-running the same problem.
//!
//! * GEMM (`dot_general`, incl. batched/permuted specs and fused
//!   epilogues) and the clustered LUT matmul (u8 and 4/6/8-bit packed)
//!   must be **bit-for-bit** equal to scalar at thread budgets 1/2/4;
//! * the bitwise-safe elementwise set (negate/abs/sqrt/floor/ceil,
//!   add/subtract/multiply/divide with scalar broadcasts) must be
//!   bit-for-bit equal through the planned executor;
//! * the SIMD softmax inherits the fused kernel's existing contract:
//!   within **4 ULP** of the classic reduce/exp/divide chain, and
//!   bit-identical across thread budgets at each level.

use std::sync::Mutex;

use clusterformer::clustering::packing::pack_indices;
use clusterformer::hlo::HloModule;
use clusterformer::runtime::interp::clustered::{lut_matmul_packed, lut_matmul_u8, prepare};
use clusterformer::runtime::interp::gemm::{dot_general, DotSpec};
use clusterformer::runtime::interp::{
    detected_kernel_isa, evaluate_unplanned, force_kernel_isa, InterpExecutor, KernelIsa,
};
use clusterformer::runtime::{Executor as _, ThreadBudget};
use clusterformer::tensor::Tensor;
use clusterformer::testing::prop::{check, ulp_dist, Gen};
use clusterformer::util::rng::Pcg32;

/// Serializes every test that forces a dispatch level: the override is
/// process-global (pool workers read it too), so concurrent forcing
/// tests would trample each other.
static ISA_LOCK: Mutex<()> = Mutex::new(());

/// RAII: holds the lock for the duration of a forcing block and always
/// restores normal resolution, including on assertion unwind.
struct IsaGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for IsaGuard<'_> {
    fn drop(&mut self) {
        force_kernel_isa(None);
    }
}

fn isa_guard() -> IsaGuard<'static> {
    IsaGuard(ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
}

/// The dispatch levels this host can actually run: always Scalar, plus
/// the detected vector level when there is one. Forcing a level the
/// hardware lacks would make the dispatcher call `#[target_feature]`
/// kernels the CPU cannot execute, so only detected levels are eligible.
fn levels() -> Vec<KernelIsa> {
    let mut v = vec![KernelIsa::Scalar];
    let d = detected_kernel_isa();
    if d != KernelIsa::Scalar {
        v.push(d);
    }
    v
}

fn rand_tensor(g: &mut Gen, dims: &[usize], scale: f32) -> Tensor {
    let n: usize = dims.iter().product();
    let vals: Vec<f32> = (0..n).map(|_| g.f32_normal() * scale).collect();
    Tensor::from_f32(dims.to_vec(), &vals).unwrap()
}

#[test]
fn prop_gemm_bitwise_across_isa_levels() {
    // Ragged shapes on purpose: n sweeps across the 8-lane (AVX2) and
    // 4-lane (NEON) boundaries so the vector body, the scalar column
    // tail, and the < MR row tail all get exercised.
    check("GEMM scalar == SIMD (bitwise)", 40, |g| {
        let b = g.usize(1, 2);
        let m = g.usize(1, 13);
        let k = g.usize(1, 40);
        let n = g.usize(1, 21);
        let batched = g.bool();
        let (ld, rd, spec) = if batched {
            (
                vec![b, m, k],
                vec![b, n, k],
                DotSpec {
                    lhs_contracting: vec![2],
                    rhs_contracting: vec![2],
                    lhs_batch: vec![0],
                    rhs_batch: vec![0],
                },
            )
        } else {
            (
                vec![m, k],
                vec![k, n],
                DotSpec {
                    lhs_contracting: vec![1],
                    rhs_contracting: vec![0],
                    ..Default::default()
                },
            )
        };
        let lhs = rand_tensor(g, &ld, 1.0);
        let rhs = rand_tensor(g, &rd, 1.0);
        let _g = isa_guard();
        force_kernel_isa(Some(KernelIsa::Scalar));
        let want = dot_general(&lhs, &rhs, &spec, 1).unwrap();
        for isa in levels() {
            force_kernel_isa(Some(isa));
            for threads in [1usize, 2, 4] {
                let got = dot_general(&lhs, &rhs, &spec, threads).unwrap();
                assert_eq!(
                    got,
                    want,
                    "isa={} threads={threads} dims {ld:?} x {rd:?}",
                    isa.name()
                );
            }
        }
    });
}

#[test]
fn prop_lut_matmul_bitwise_across_isa_levels() {
    // m sweeps across the row-group width so both the lane-wide body
    // and the scalar remainder rows run; 4/6/8-bit packed weights cover
    // every decode path feeding the SIMD tile.
    check("LUT matmul scalar == SIMD (bitwise)", 30, |g| {
        let m = g.usize(1, 19);
        let k = g.usize(1, 48);
        let n = g.usize(1, 30);
        // 16/64/256 clusters pack to 4/6/8 bits respectively, covering
        // every bit-unpack path feeding the SIMD column tile.
        let bits = *g.pick(&[4u32, 6, 8]);
        let clusters = 1usize << bits;
        let x: Vec<f32> = (0..m * k).map(|_| g.f32_normal()).collect();
        let idx: Vec<u8> = (0..k * n).map(|_| g.usize(0, clusters - 1) as u8).collect();
        let cb: Vec<f32> = (0..clusters).map(|_| g.f32_normal()).collect();
        let prep = prepare(&idx, k, n, &cb, Some(clusters)).unwrap();

        let _g = isa_guard();
        force_kernel_isa(Some(KernelIsa::Scalar));
        let want_u8 = lut_matmul_u8(&x, m, k, n, &idx, &cb, 1).unwrap();
        let want_packed = lut_matmul_packed(&x, m, &prep, 1).unwrap();
        assert_eq!(want_u8, want_packed);
        for isa in levels() {
            force_kernel_isa(Some(isa));
            for threads in [1usize, 2, 4] {
                assert_eq!(
                    lut_matmul_u8(&x, m, k, n, &idx, &cb, threads).unwrap(),
                    want_u8,
                    "u8 isa={} threads={threads} m={m} k={k} n={n}",
                    isa.name()
                );
                assert_eq!(
                    lut_matmul_packed(&x, m, &prep, threads).unwrap(),
                    want_packed,
                    "packed isa={} threads={threads} m={m} k={k} n={n} bits={bits}",
                    isa.name()
                );
            }
        }
    });
}

fn elementwise_hlo(m: usize, n: usize) -> String {
    // Every op with a SIMD tag: the unary set (negate/abs/sqrt/floor/
    // ceil — sqrt sees negative inputs, pinning NaN bit patterns too)
    // and the binary set with both full-size and broadcast-scalar
    // operands. rsqrt is spelled `rsqrt` only in fused form upstream,
    // so the chain uses sqrt + divide to cover the same lanes.
    format!(
        "HloModule ew\n\
         ENTRY %e (x: f32[{m},{n}], y: f32[{m},{n}]) -> f32[{m},{n}] {{\n  \
         %x = f32[{m},{n}]{{1,0}} parameter(0)\n  \
         %y = f32[{m},{n}]{{1,0}} parameter(1)\n  \
         %half = f32[] constant(0.5)\n  \
         %a = f32[{m},{n}]{{1,0}} add(%x, %y)\n  \
         %s = f32[{m},{n}]{{1,0}} subtract(%a, %y)\n  \
         %mu = f32[{m},{n}]{{1,0}} multiply(%s, %half)\n  \
         %d = f32[{m},{n}]{{1,0}} divide(%mu, %y)\n  \
         %ng = f32[{m},{n}]{{1,0}} negate(%d)\n  \
         %ab = f32[{m},{n}]{{1,0}} abs(%ng)\n  \
         %sq = f32[{m},{n}]{{1,0}} sqrt(%mu)\n  \
         %fl = f32[{m},{n}]{{1,0}} floor(%sq)\n  \
         %ce = f32[{m},{n}]{{1,0}} ceil(%ab)\n  \
         ROOT %o = f32[{m},{n}]{{1,0}} add(%fl, %ce)\n}}\n"
    )
}

#[test]
fn prop_elementwise_bitwise_across_isa_levels() {
    // Fusion is off so each op runs through the standalone SIMD entry
    // points (unary_into/inplace, binary_f32_*) rather than collapsing
    // into one fused chain. Sizes straddle the lane width.
    check("elementwise scalar == SIMD (bitwise)", 25, |g| {
        let m = g.usize(1, 9);
        let n = g.usize(1, 19);
        let hlo = elementwise_hlo(m, n);
        let x = rand_tensor(g, &[m, n], 1.3);
        let y = rand_tensor(g, &[m, n], 0.9);
        let inputs = vec![x, y];
        let _g = isa_guard();
        force_kernel_isa(Some(KernelIsa::Scalar));
        let exe = InterpExecutor::load_text(&hlo, "ew-scalar")
            .unwrap()
            .with_fusion(false);
        assert!(exe.memory_plan().is_some(), "must plan\n{hlo}");
        let want = exe.run(&inputs).unwrap();
        for isa in levels() {
            force_kernel_isa(Some(isa));
            for budget in [1usize, 2, 4] {
                let exe = InterpExecutor::load_text(&hlo, "ew-simd")
                    .unwrap()
                    .with_threads(ThreadBudget::new(budget))
                    .with_fusion(false);
                assert_eq!(
                    exe.run(&inputs).unwrap(),
                    want,
                    "isa={} budget={budget} m={m} n={n}",
                    isa.name()
                );
            }
        }
    });
}

fn gemm_epilogue_hlo(m: usize, k: usize, n: usize) -> String {
    format!(
        "HloModule gemm_ep\n\
         ENTRY %e (x: f32[{m},{k}], w: f32[{k},{n}], bias: f32[{n}], res: f32[{m},{n}]) -> f32[{m},{n}] {{\n\
         \x20 %x = f32[{m},{k}]{{1,0}} parameter(0)\n\
         \x20 %w = f32[{k},{n}]{{1,0}} parameter(1)\n\
         \x20 %bias = f32[{n}]{{0}} parameter(2)\n\
         \x20 %res = f32[{m},{n}]{{1,0}} parameter(3)\n\
         \x20 %d = f32[{m},{n}]{{1,0}} dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 %bb = f32[{m},{n}]{{1,0}} broadcast(%bias), dimensions={{1}}\n\
         \x20 %s = f32[{m},{n}]{{1,0}} add(%d, %bb)\n\
         \x20 %a = f32[{m},{n}]{{1,0}} tanh(%s)\n\
         \x20 ROOT %o = f32[{m},{n}]{{1,0}} add(%res, %a)\n}}\n"
    )
}

#[test]
fn gemm_epilogue_bitwise_across_isa_levels() {
    // 2*96*97*99 flops clear the GEMM parallel threshold, and 97/99 are
    // deliberately not lane multiples: the fused epilogue must see the
    // same accumulator bits whether the tile body or the remainder
    // produced them, at every level and budget.
    let (m, k, n) = (96usize, 97, 99);
    let hlo = gemm_epilogue_hlo(m, k, n);
    let mut rng = Pcg32::new(2106);
    let mut t = |dims: &[usize], scale: f32| {
        let len: usize = dims.iter().product();
        let vals: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * scale).collect();
        Tensor::from_f32(dims.to_vec(), &vals).unwrap()
    };
    let inputs = vec![
        t(&[m, k], 0.5),
        t(&[k, n], 0.3),
        t(&[n], 0.2),
        t(&[m, n], 0.4),
    ];
    let _g = isa_guard();
    force_kernel_isa(Some(KernelIsa::Scalar));
    let scalar_exe = InterpExecutor::load_text(&hlo, "gemm-ep-scalar")
        .unwrap()
        .with_fusion(true);
    assert_eq!(scalar_exe.memory_plan().expect("must plan").fused_epilogues(), 1);
    let want = scalar_exe.run(&inputs).unwrap();
    for isa in levels() {
        force_kernel_isa(Some(isa));
        for budget in [1usize, 2, 4] {
            let exe = InterpExecutor::load_text(&hlo, "gemm-ep-simd")
                .unwrap()
                .with_threads(ThreadBudget::new(budget))
                .with_fusion(true);
            assert_eq!(
                exe.run(&inputs).unwrap(),
                want,
                "isa={} budget={budget}",
                isa.name()
            );
        }
    }
}

fn softmax_hlo(r: usize, c: usize) -> String {
    format!(
        "HloModule sm\n\
         %max_f (p0: f32[], p1: f32[]) -> f32[] {{\n  \
         %p0 = f32[] parameter(0)\n  \
         %p1 = f32[] parameter(1)\n  \
         ROOT %r = f32[] maximum(%p0, %p1)\n}}\n\
         %add_f (q0: f32[], q1: f32[]) -> f32[] {{\n  \
         %q0 = f32[] parameter(0)\n  \
         %q1 = f32[] parameter(1)\n  \
         ROOT %r2 = f32[] add(%q0, %q1)\n}}\n\
         ENTRY %e (a: f32[{r},{c}]) -> f32[{r},{c}] {{\n  \
         %a = f32[{r},{c}]{{1,0}} parameter(0)\n  \
         %ninf = f32[] constant(-inf)\n  \
         %mx = f32[{r}]{{0}} reduce(%a, %ninf), dimensions={{1}}, to_apply=%max_f\n  \
         %mxb = f32[{r},{c}]{{1,0}} broadcast(%mx), dimensions={{0}}\n  \
         %cs = f32[{r},{c}]{{1,0}} subtract(%a, %mxb)\n  \
         %x = f32[{r},{c}]{{1,0}} exponential(%cs)\n  \
         %zero = f32[] constant(0)\n  \
         %sm = f32[{r}]{{0}} reduce(%x, %zero), dimensions={{1}}, to_apply=%add_f\n  \
         %smb = f32[{r},{c}]{{1,0}} broadcast(%sm), dimensions={{0}}\n  \
         ROOT %o = f32[{r},{c}]{{1,0}} divide(%x, %smb)\n}}\n"
    )
}

#[test]
fn prop_softmax_within_4_ulp_at_every_isa_level() {
    check("softmax <= 4 ULP at every ISA level", 20, |g| {
        let r = g.usize(1, 9);
        let c = g.usize(2, 33);
        let hlo = softmax_hlo(r, c);
        let a = rand_tensor(g, &[r, c], 1.5);
        let module = HloModule::parse(&hlo).unwrap();
        let classic = evaluate_unplanned(&module, &[&a]).unwrap();
        let cv = classic[0].as_f32().unwrap();
        let _g = isa_guard();
        for isa in levels() {
            force_kernel_isa(Some(isa));
            let mut per_budget: Vec<Vec<f32>> = Vec::new();
            for budget in [1usize, 2, 4] {
                let exe = InterpExecutor::load_text(&hlo, "softmax-simd")
                    .unwrap()
                    .with_threads(ThreadBudget::new(budget))
                    .with_fusion(true);
                assert_eq!(exe.memory_plan().expect("must plan").fused_softmax(), 1);
                let out = exe.run(std::slice::from_ref(&a)).unwrap();
                let ov = out[0].as_f32().unwrap();
                for (i, (f, cl)) in ov.iter().zip(&cv).enumerate() {
                    let d = ulp_dist(*f, *cl);
                    assert!(
                        d <= 4,
                        "element {i}: {f} vs classic {cl} is {d} ULP apart \
                         (isa={} budget={budget} r={r} c={c})",
                        isa.name()
                    );
                }
                per_budget.push(ov);
            }
            // Rows are lane-independent: identical bits at every budget.
            assert_eq!(per_budget[0], per_budget[1], "isa={}", isa.name());
            assert_eq!(per_budget[0], per_budget[2], "isa={}", isa.name());
        }
    });
}

#[test]
fn forced_packed_bits_roundtrip_into_simd_tile() {
    // Direct 4/6/8-bit packed inputs through the public packing API (not
    // `prepare`'s auto-width) so the SIMD column decode is pinned against
    // hand-packed bytes at each level.
    let (m, k, n) = (9usize, 21, 17);
    let mut rng = Pcg32::new(616);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    for bits in [4u32, 6, 8] {
        let max = ((1usize << bits) - 1).min(255);
        let idx: Vec<u8> = (0..k * n).map(|_| rng.range(0, max) as u8).collect();
        let cb: Vec<f32> = (0..=max).map(|_| rng.normal() as f32).collect();
        // Sanity: the packed form these tests rely on round-trips.
        let packed = pack_indices(&idx, bits).unwrap();
        assert!(!packed.is_empty());
        let prep = prepare(&idx, k, n, &cb, Some(max + 1)).unwrap();
        let _g = isa_guard();
        force_kernel_isa(Some(KernelIsa::Scalar));
        let want = lut_matmul_packed(&x, m, &prep, 1).unwrap();
        for isa in levels() {
            force_kernel_isa(Some(isa));
            for threads in [1usize, 2, 4] {
                assert_eq!(
                    lut_matmul_packed(&x, m, &prep, threads).unwrap(),
                    want,
                    "bits={bits} isa={} threads={threads}",
                    isa.name()
                );
            }
        }
    }
}
