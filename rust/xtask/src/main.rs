//! `cargo run -p xtask -- lint` — a repo-local static pass enforcing
//! invariants the compiler can't (ISSUE 9). No dependencies, std only:
//! the rules are deliberately line-level and dumb, because every one of
//! them guards a convention this codebase states in prose somewhere and
//! has already slipped on at least once.
//!
//! Rules:
//!
//! * `no-unwrap` — no `.unwrap()` / `.expect(` in the hot-path files
//!   (`runtime/interp/{plan,arena,plan_cache,decode}.rs`,
//!   `coordinator/{router,worker,server}.rs`): a panic there takes down
//!   a serving worker mid-request. Test modules (below `#[cfg(test)]`)
//!   are exempt.
//! * `no-thread-spawn` — `std::thread::spawn` / `thread::Builder` only
//!   in `runtime/interp/pool_exec.rs` (the persistent kernel pool);
//!   everything else must borrow its lanes from the pool so the
//!   `CLUSTERFORMER_THREADS` budget actually bounds the process.
//!   Test modules are exempt.
//! * `safety-comment` — every `unsafe` block, `unsafe fn`, and
//!   `unsafe impl` in `src/` must be preceded by a `// SAFETY:` comment
//!   (or a `/// # Safety` doc section) stating the invariant that makes
//!   it sound. Bare `unsafe fn(...)` pointer *types* are not flagged.
//! * `no-instant` — no `Instant::now()` in the kernel files
//!   (`ops/gemm/clustered/aligned/pool_exec/arena.rs`): a syscall-ish
//!   clock read inside a per-element loop is a profiling artifact that
//!   ships; timing belongs in benches and the coordinator.
//!
//! Allowlisting: a finding is suppressed by an annotation on the same
//! line or the line above, of the form
//! `// lint:allow(<rule>): <justification>` — the justification is
//! mandatory (an empty reason is itself a finding). CI runs this pass;
//! the only standing entries are documented in the README.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files where a panic is a serving outage, not a bug report.
const HOT_PATH_FILES: &[&str] = &[
    "runtime/interp/plan.rs",
    "runtime/interp/arena.rs",
    "runtime/interp/plan_cache.rs",
    "runtime/interp/decode.rs",
    "coordinator/router.rs",
    "coordinator/worker.rs",
    "coordinator/server.rs",
    "coordinator/http.rs",
    "coordinator/conn.rs",
];

/// The one file allowed to spawn OS threads (the persistent pool).
const SPAWN_ALLOWED: &str = "runtime/interp/pool_exec.rs";

/// Kernel files where a clock read means someone left profiling code in
/// a per-element loop.
const KERNEL_FILES: &[&str] = &[
    "runtime/interp/ops.rs",
    "runtime/interp/gemm.rs",
    "runtime/interp/clustered.rs",
    "runtime/interp/aligned.rs",
    "runtime/interp/pool_exec.rs",
    "runtime/interp/arena.rs",
];

struct Finding {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::from(2)
        }
    }
}

fn lint() -> ExitCode {
    // CARGO_MANIFEST_DIR = <repo>/rust/xtask; the tree under lint is
    // <repo>/rust/src.
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives inside the rust crate")
        .to_path_buf();
    let src = crate_root.join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();

    let mut findings = Vec::new();
    let mut lines_scanned = 0usize;
    for path in &files {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                findings.push(Finding {
                    file: path.clone(),
                    line: 0,
                    rule: "io",
                    message: format!("unreadable: {e}"),
                });
                continue;
            }
        };
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        lines_scanned += text.lines().count();
        check_file(path, &rel, &text, &mut findings);
    }

    if findings.is_empty() {
        println!(
            "xtask lint: {} files, {} lines, 0 findings",
            files.len(),
            lines_scanned
        );
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            eprintln!(
                "{}:{}: [{}] {}",
                f.file.display(),
                f.line,
                f.rule,
                f.message
            );
        }
        eprintln!("xtask lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// The code part of a line: everything before a `//` that is not inside
/// a string literal. Good enough for line-level rules — raw strings and
/// multiline literals in this codebase never contain the tokens the
/// rules match on.
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'"' if !in_str => in_str = true,
            b'"' if in_str && (i == 0 || b[i - 1] != b'\\') => in_str = false,
            b'/' if !in_str && i + 1 < b.len() && b[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

/// Parse a `lint:allow(<rules>): <reason>` annotation out of a line, if
/// present. Returns (rules, reason).
fn allow_annotation(line: &str) -> Option<(Vec<String>, String)> {
    let at = line.find("lint:allow(")?;
    let rest = &line[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rules = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let reason = rest[close + 1..]
        .trim_start_matches([':', '-', ' '])
        .trim()
        .to_string();
    Some((rules, reason))
}

/// Whether line `idx` (0-based) carries or inherits an allow annotation
/// for `rule`: on the flagged line itself, or anywhere in the contiguous
/// comment block directly above it. Flags an empty justification as its
/// own finding.
fn allowed(
    path: &Path,
    lines: &[&str],
    idx: usize,
    rule: &str,
    findings: &mut Vec<Finding>,
) -> bool {
    let mut look = idx;
    loop {
        if let Some((rules, reason)) = allow_annotation(lines[look]) {
            if rules.iter().any(|r| r == rule) {
                if reason.is_empty() {
                    findings.push(Finding {
                        file: path.to_path_buf(),
                        line: look + 1,
                        rule: "allow-without-reason",
                        message: format!(
                            "lint:allow({rule}) needs a justification after the closing paren"
                        ),
                    });
                }
                return true;
            }
        }
        if look == 0 || !lines[look - 1].trim_start().starts_with("//") {
            return false;
        }
        look -= 1;
    }
}

/// First line (0-based) of the file's trailing `#[cfg(test)]` region,
/// or `usize::MAX` when there is none. Test modules sit at the bottom
/// of every file in this repo, so everything after the marker is test
/// code.
fn test_region_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| l.trim() == "#[cfg(test)]")
        .unwrap_or(usize::MAX)
}

fn check_file(path: &Path, rel: &str, text: &str, findings: &mut Vec<Finding>) {
    let lines: Vec<&str> = text.lines().collect();
    let test_start = test_region_start(&lines);
    let hot = HOT_PATH_FILES.contains(&rel);
    let kernel = KERNEL_FILES.contains(&rel);
    let spawn_ok = rel == SPAWN_ALLOWED;

    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        let in_tests = i >= test_start;

        if hot
            && !in_tests
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(path, &lines, i, "no-unwrap", findings)
        {
            findings.push(Finding {
                file: path.to_path_buf(),
                line: i + 1,
                rule: "no-unwrap",
                message: "unwrap()/expect() on a hot path: return a contextful error, \
                          or annotate a proven invariant with \
                          `// lint:allow(no-unwrap): <why it cannot fail>`"
                    .to_string(),
            });
        }

        if !spawn_ok
            && !in_tests
            && (code.contains("thread::spawn") || code.contains("thread::Builder"))
            && !allowed(path, &lines, i, "no-thread-spawn", findings)
        {
            findings.push(Finding {
                file: path.to_path_buf(),
                line: i + 1,
                rule: "no-thread-spawn",
                message: "OS threads are spawned only by the kernel pool \
                          (runtime/interp/pool_exec.rs); use par_for / par_for_rows, or \
                          annotate a supervised lifecycle thread with \
                          `// lint:allow(no-thread-spawn): <why>`"
                    .to_string(),
            });
        }

        if kernel
            && !in_tests
            && code.contains("Instant::now")
            && !allowed(path, &lines, i, "no-instant", findings)
        {
            findings.push(Finding {
                file: path.to_path_buf(),
                line: i + 1,
                rule: "no-instant",
                message: "clock reads inside kernel files ship profiling artifacts; \
                          time in benches or the coordinator instead"
                    .to_string(),
            });
        }

        if let Some(col) = unsafe_site(code) {
            if !has_safety_comment(&lines, i, raw, col)
                && !allowed(path, &lines, i, "safety-comment", findings)
            {
                findings.push(Finding {
                    file: path.to_path_buf(),
                    line: i + 1,
                    rule: "safety-comment",
                    message: "unsafe without a `// SAFETY:` comment (or `/// # Safety` doc \
                              section) stating the invariant that makes it sound"
                        .to_string(),
                });
            }
        }
    }
}

/// Byte offset of an `unsafe` keyword on this (comment-stripped) line
/// that starts an unsafe block, fn, or impl — `None` for pointer types
/// (`unsafe fn(`), mentions inside identifiers, and plain-text uses.
fn unsafe_site(code: &str) -> Option<usize> {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("unsafe").map(|p| p + from) {
        from = pos + "unsafe".len();
        // Left word boundary: reject `an_unsafe_thing`. The right
        // boundary falls out of the dispatch below — only `{`, `impl`,
        // `fn`, and end-of-line count as unsafe sites, so `unsafely`
        // (raw = "ly") matches none of them.
        if pos > 0 && (b[pos - 1].is_ascii_alphanumeric() || b[pos - 1] == b'_') {
            continue;
        }
        let raw_after = &code[pos + "unsafe".len()..];
        if !raw_after.is_empty() && !raw_after.starts_with([' ', '\t', '{']) {
            continue;
        }
        let after = raw_after.trim_start();
        if after.starts_with('{') || after.starts_with("impl") {
            return Some(pos);
        }
        if let Some(rest) = after.strip_prefix("fn") {
            let rest = rest.trim_start();
            // `unsafe fn(` with no name is a function-pointer *type*
            // (e.g. a struct field); declarations have an identifier.
            if rest.starts_with('(') {
                continue;
            }
            return Some(pos);
        }
        // `unsafe` at end of line: the `{` opens on the next line.
        if after.is_empty() {
            return Some(pos);
        }
    }
    None
}

/// Whether the unsafe site on line `idx` has a SAFETY comment: trailing
/// on the same line, or in the contiguous run of comment / attribute /
/// blank lines directly above (which is where `/// # Safety` doc
/// sections and between-attribute `// SAFETY:` comments live).
fn has_safety_comment(lines: &[&str], idx: usize, raw: &str, _col: usize) -> bool {
    let mentions_safety =
        |l: &str| l.contains("SAFETY:") || l.contains("# Safety") || l.contains("Safety:");
    // Trailing comment on the same line.
    if let Some(at) = raw.find("//") {
        if mentions_safety(&raw[at..]) {
            return true;
        }
    }
    let mut k = idx;
    let mut budget = 100;
    while k > 0 && budget > 0 {
        k -= 1;
        budget -= 1;
        let t = lines[k].trim();
        let is_carrier = t.starts_with("//")
            || t.starts_with("#[")
            || t.starts_with("#!")
            || t.ends_with(']') && t.starts_with('#')
            || t.is_empty();
        if t.starts_with("//") && mentions_safety(t) {
            return true;
        }
        if !is_carrier {
            // One structural line of slack: dispatch-match SAFETY
            // comments sometimes sit above the match arm pattern, e.g.
            //   // SAFETY: ...
            //   KernelIsa::Avx2 => unsafe { ... }
            // where the arm itself is the unsafe line; but a comment
            // above a *different* preceding statement must not leak
            // through. Stop at the first non-comment/attr line.
            return false;
        }
    }
    false
}
