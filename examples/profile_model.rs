//! Profile a model the way the paper's §III-A does: a per-op-category
//! breakdown of compute (Fig. 2) and memory (Fig. 3), from both the
//! static HLO cost analysis and measured micro-module wall times.
//!
//! ```bash
//! make artifacts && cargo run --release --example profile_model
//! ```

use std::time::Instant;

use clusterformer::hlo::{CostAnalysis, HloModule};
use clusterformer::model::Registry;
use clusterformer::runtime::{default_backend, Backend as _, Executor as _};
use clusterformer::tensor::{Dtype, Tensor};

fn main() -> anyhow::Result<()> {
    let registry = Registry::load("artifacts")?;
    let backend = default_backend()?;

    for model in ["vit", "deit"] {
        let entry = registry.manifest.model(model)?;
        let file = &entry.hlo_baseline[&8];
        let module = HloModule::parse_file(registry.manifest.path(file))?;
        let cost = CostAnalysis::of(&module)?;
        println!(
            "\n== {model} (batch 8): static HLO analysis — {:.1} MFLOP/pass, {} instructions ==",
            cost.total_flops() / 1e6,
            cost.opcode_counts.values().sum::<usize>()
        );
        println!("{:<16} {:>8} {:>8}", "category", "flops%", "bytes%");
        let tb = cost.total_bytes().max(1.0);
        for (cat, frac) in cost.flop_breakdown() {
            println!(
                "{:<16} {:>7.1}% {:>7.1}%",
                cat.name(),
                frac * 100.0,
                cost.bytes.get(&cat).copied().unwrap_or(0.0) / tb * 100.0
            );
        }
    }

    // Measured micro-module wall times at model shapes (Fig. 2 companion).
    println!("\n== measured micro-kernel times (model shapes, batch 8) ==");
    let mut rows = Vec::new();
    for (op, (file, shapes)) in &registry.manifest.micro_hlo {
        let exe = backend.load_hlo(&registry.manifest.path(file))?;
        let inputs: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::zeros(Dtype::F32, s.clone()))
            .collect();
        // warmup + measure
        exe.run(&inputs)?;
        let t0 = Instant::now();
        let iters = 50;
        for _ in 0..iters {
            exe.run(&inputs)?;
        }
        rows.push((op.clone(), t0.elapsed().as_secs_f64() / iters as f64));
    }
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    let total: f64 = rows.iter().map(|(_, t)| t).sum();
    for (op, t) in &rows {
        println!(
            "{:<16} {:>9.1} µs  {:>5.1}% of micro total",
            op,
            t * 1e6,
            t / total * 100.0
        );
    }
    Ok(())
}
