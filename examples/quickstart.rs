//! Quickstart: load the AOT artifacts, classify a few validation images
//! with the FP32 baseline and the clustered-64 model, and compare.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::worker::VariantExecutor;
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::{default_backend, Backend as _};

fn main() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let mut registry = Registry::load("artifacts")?;
    let class_names = registry.manifest.class_names.clone();
    let (images, labels) = registry.val_set()?;

    println!("== clusterformer quickstart ==");
    println!("backend: {}", backend.name());

    // Load both representations of the ViT.
    let baseline =
        VariantExecutor::load(backend.as_ref(), &mut registry, "vit", VariantKey::Baseline)?;
    let clustered = VariantExecutor::load(
        backend.as_ref(),
        &mut registry,
        "vit",
        VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
    )?;
    println!(
        "baseline weight stream: {:.2} MB | clustered-64: {:.2} MB ({:.2}x) + {} B table",
        baseline.weight_stream_bytes as f64 / 1e6,
        clustered.weight_stream_bytes as f64 / 1e6,
        baseline.weight_stream_bytes as f64 / clustered.weight_stream_bytes as f64,
        clustered.table_bytes,
    );

    // Classify the first 8 validation images with both.
    let batch = images.slice_rows(0, 8)?;
    let (rows_b, _) = baseline.execute(&batch)?;
    let (rows_c, _) = clustered.execute(&batch)?;
    println!("\n{:<4} {:<10} {:<22} {:<22}", "img", "truth", "baseline", "clustered-64");
    let mut agree = 0;
    for i in 0..8 {
        let pick = |row: &[f32]| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, &v)| (c, v))
                .unwrap()
        };
        let (cb, vb) = pick(&rows_b[i]);
        let (cc, vc) = pick(&rows_c[i]);
        if cb == cc {
            agree += 1;
        }
        let name = |c: usize| {
            class_names.get(c).cloned().unwrap_or_else(|| c.to_string())
        };
        println!(
            "{:<4} {:<10} {:<22} {:<22}",
            i,
            name(labels[i] as usize),
            format!("{} ({vb:.2})", name(cb)),
            format!("{} ({vc:.2})", name(cc)),
        );
    }
    println!("\nbaseline and clustered agree on {agree}/8 predictions");
    Ok(())
}
