//! Compression sweep in pure Rust: cluster the trained ViT weights at
//! every (scheme, cluster-count) with the Rust K-means toolkit — no
//! Python needed — and report size, reconstruction error, and the
//! accuracy of the c=64 point through the runtime.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_sweep
//! ```

use clusterformer::clustering::{ClusterScheme, Quantizer};
use clusterformer::coordinator::eval::evaluate;
use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::default_backend;

fn main() -> anyhow::Result<()> {
    let mut registry = Registry::load("artifacts")?;
    let entry = registry.manifest.model("vit")?.clone();
    let names = entry.clustered_names();
    let weights = registry.weights("vit")?.clone();

    println!("== Rust-side compression sweep (vit, {} tensors) ==", names.len());
    println!(
        "{:<10} {:>5} {:>10} {:>10} {:>8} {:>12} {:>10}",
        "scheme", "c", "orig MB", "comp MB", "ratio", "table B", "mse"
    );
    for scheme in [ClusterScheme::Entire, ClusterScheme::PerLayer] {
        for c in [8usize, 16, 32, 64, 128, 256] {
            let t0 = std::time::Instant::now();
            let ct = Quantizer::new(c, scheme).run(&names, &weights)?;
            let mse = ct.quantization_mse(&weights)?;
            println!(
                "{:<10} {:>5} {:>10.2} {:>10.2} {:>7.2}x {:>12} {:>10.2e}  ({:.2}s)",
                scheme.name(),
                c,
                ct.original_bytes() as f64 / 1e6,
                ct.compressed_bytes() as f64 / 1e6,
                ct.original_bytes() as f64 / ct.compressed_bytes() as f64,
                ct.table_bytes(),
                mse,
                t0.elapsed().as_secs_f64(),
            );
        }
    }

    // Cross-check: the Rust-clustered representation should match the
    // Python-clustered artifact in reconstruction error.
    let ct = Quantizer::new(64, ClusterScheme::PerLayer).run(&names, &weights)?;
    let py = registry.clustered("vit", ClusterScheme::PerLayer, 64)?;
    let mse_rs = ct.quantization_mse(&weights)?;
    let mse_py = py.quantization_mse(&weights)?;
    println!(
        "\ncross-validation vs python artifact (perlayer, c=64): rust mse {mse_rs:.3e} vs python mse {mse_py:.3e} ({:+.2}%)",
        (mse_rs / mse_py - 1.0) * 100.0
    );

    // And the c=64 accuracy through the actual runtime.
    let backend = default_backend()?;
    for key in [
        VariantKey::Baseline,
        VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 },
    ] {
        let r = evaluate(backend.as_ref(), &mut registry, "vit", key, 256)?;
        println!(
            "runtime accuracy {}: top1={:.4} top5={:.4} ({:.1} img/s)",
            r.variant, r.top1, r.top5, r.images_per_s
        );
    }
    Ok(())
}
