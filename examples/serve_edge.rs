//! End-to-end serving driver (DESIGN.md E7) — the required proof that all
//! layers compose: Pallas kernels (L1) lowered into the HLO artifacts
//! (L2) are served by the Rust coordinator (L3) on a real workload.
//!
//! Starts the server with the ViT baseline AND the clustered-64 variant,
//! drives an open-loop Poisson request stream from the validation set at
//! increasing rates, and reports per-variant latency percentiles,
//! throughput, accuracy-on-served-traffic, and the memory footprint each
//! representation streams per inference.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_edge
//! ```

use std::time::{Duration, Instant};

use clusterformer::clustering::ClusterScheme;
use clusterformer::coordinator::{
    BatchPolicy, BatcherConfig, Server, ServerConfig,
};
use clusterformer::model::{Registry, VariantKey};
use clusterformer::tensor::Tensor;
use clusterformer::util::rng::Pcg32;

const RATES: &[f64] = &[20.0, 60.0, 120.0];
const DURATION_S: f64 = 6.0;

fn main() -> anyhow::Result<()> {
    let clustered =
        VariantKey::Clustered { scheme: ClusterScheme::PerLayer, clusters: 64 };
    println!("== serve_edge: e2e serving driver ==");
    println!("starting server (compiles 2 variants x 3 batch sizes)...");
    let server = Server::start(ServerConfig {
        artifacts_dir: "artifacts".into(),
        backend: clusterformer::runtime::BackendKind::from_env()?,
        targets: vec![
            ("vit".to_string(), VariantKey::Baseline),
            ("vit".to_string(), clustered),
        ],
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(15),
            policy: BatchPolicy::Adaptive,
            queue_cap: 512,
        },
        threads: clusterformer::runtime::ThreadBudget::from_env(),
    })?;

    let registry = Registry::load("artifacts")?;
    let (images, labels) = registry.val_set()?;
    let n_val = images.shape()[0];

    println!(
        "\n{:<22} {:>7} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "target", "rate", "p50", "p99", "thruput", "accuracy", "done"
    );
    for &target in &["vit/baseline", "vit/perlayer_64"] {
        for &rate in RATES {
            let mut rng = Pcg32::new(42);
            let t0 = Instant::now();
            let mut pending = Vec::new();
            let mut truth = Vec::new();
            let mut i = 0usize;
            while t0.elapsed().as_secs_f64() < DURATION_S {
                std::thread::sleep(Duration::from_secs_f64(
                    rng.exponential(rate).min(0.5),
                ));
                let row = i % n_val;
                let img = single_image(&images, row)?;
                pending.push(server.router.submit(target, img)?.1);
                truth.push(labels[row]);
                i += 1;
            }
            let mut lat = Vec::new();
            let mut correct = 0usize;
            let mut done = 0usize;
            for (rx, label) in pending.iter().zip(&truth) {
                if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
                    if !resp.logits.is_empty() {
                        done += 1;
                        lat.push(resp.latency_s);
                        if resp.predicted == *label as usize {
                            correct += 1;
                        }
                    }
                }
            }
            lat.sort_by(|a, b| a.total_cmp(b));
            let pct = |q: f64| {
                clusterformer::util::stats::percentile_sorted(&lat, q) * 1e3
            };
            println!(
                "{:<22} {:>6.0}/s {:>8.2}ms {:>8.2}ms {:>7.1}/s {:>9.4} {:>5}/{}",
                target,
                rate,
                pct(0.50),
                pct(0.99),
                done as f64 / t0.elapsed().as_secs_f64(),
                correct as f64 / done.max(1) as f64,
                done,
                i
            );
        }
    }

    println!("\n== coordinator metrics ==\n{}", server.snapshot().markdown());
    let mut reg = Registry::load("artifacts")?;
    let base = reg.variant("vit", VariantKey::Baseline)?;
    let clus = reg.variant("vit", clustered)?;
    println!(
        "weight stream per inference: baseline {:.2} MB -> clustered {:.2} MB ({:.2}x reduction)",
        base.weight_stream_bytes as f64 / 1e6,
        clus.weight_stream_bytes as f64 / 1e6,
        base.weight_stream_bytes as f64 / clus.weight_stream_bytes as f64
    );
    server.shutdown();
    Ok(())
}

fn single_image(images: &Tensor, row: usize) -> anyhow::Result<Tensor> {
    let mut img = images.slice_rows(row, row + 1)?;
    let shape = img.shape()[1..].to_vec();
    img.reshape(shape)?;
    Ok(img)
}
