use clusterformer::model::{Registry, VariantKey};
use clusterformer::runtime::{default_backend, Backend as _, Executor as _, ResidentExecutor as _};

fn main() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let mut registry = Registry::load("artifacts")?;
    let variant = registry.variant("vit", VariantKey::Baseline)?;
    let (images, _labels) = registry.val_set()?;
    let img1 = images.slice_rows(0, 1)?;
    println!("img1 shape {:?} bytes {}", img1.shape(), img1.nbytes());
    for (i, t) in variant.weight_inputs.iter().enumerate().take(4) {
        println!("w[{i}] shape {:?} bytes {}", t.shape(), t.nbytes());
    }
    // literal path
    let exe = backend.load_hlo(&variant.hlo_paths[&1])?;
    let mut inputs = vec![img1.clone()];
    inputs.extend(variant.weight_inputs.iter().cloned());
    println!("n inputs {}", inputs.len());
    let out = exe.run(&inputs)?;
    println!("literal path OK: out shape {:?}", out[0].shape());
    // resident path
    let res = exe.with_resident(1, std::sync::Arc::new(variant.weight_inputs.clone()))?;
    let out2 = res.run(std::slice::from_ref(&img1))?;
    println!("resident path OK: out {:?}", out2[0].shape());
    let a = out[0].as_f32()?;
    let b = out2[0].as_f32()?;
    for (x, y) in a.iter().zip(b.iter()) {
        assert!((x - y).abs() < 1e-5);
    }
    println!("match");
    Ok(())
}
