"""AOT pipeline: train -> cluster -> lower -> export artifacts.

Runs once under `make artifacts`; the Rust binary is self-contained
afterwards. Produces, under ``artifacts/``:

  * ``manifest.json``            — the contract consumed by rust/src/model
  * ``{model}_weights.tpak``     — trained FP32 parameters
  * ``{model}_clustered_{scheme}_{c}.tpak`` — u8 indices + padded codebooks
  * ``{model}_{batch}_baseline.hlo.txt``   — kernel-path forward, FP32
  * ``{model}_{batch}_clustered.hlo.txt``  — kernel-path forward, clustered
  * ``micro_{op}.hlo.txt``       — per-op-category micro modules (Fig. 2)
  * ``val.tpak``                 — validation images + labels
  * ``{model}_goldens.tpak``     — logits oracles for Rust integration tests
  * ``accuracy_python.json``     — python-side accuracy sweep (cross-check)

HLO is exported as **text**: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import kmeans as K
from . import model as M
from . import tnsr
from . import train as T
from .kernels import ref

BATCH_SIZES = (1, 8, 32)
GOLDEN_N = 32  # images in the golden logits fixtures


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def model_configs() -> dict[str, M.ModelConfig]:
    dim = _env_int("CLUSTERFORMER_DIM", 192)
    depth = _env_int("CLUSTERFORMER_DEPTH", 6)
    heads = _env_int("CLUSTERFORMER_HEADS", 3)
    return {
        "vit": M.ModelConfig(name="vit", dim=dim, depth=depth, heads=heads),
        "deit": M.ModelConfig(
            name="deit", dim=dim, depth=depth, heads=heads, distilled=True
        ),
    }


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_baseline(cfg: M.ModelConfig, batch: int) -> str:
    fn = M.make_baseline_fn(cfg, use_kernels=True)
    img = jax.ShapeDtypeStruct((batch, cfg.img_size, cfg.img_size, 3), jnp.float32)
    flat = [
        jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in M.param_manifest(cfg)
    ]
    return to_hlo_text(jax.jit(fn).lower(img, *flat))


def lower_clustered(cfg: M.ModelConfig, batch: int) -> str:
    fn = M.make_clustered_fn(cfg)
    img = jax.ShapeDtypeStruct((batch, cfg.img_size, cfg.img_size, 3), jnp.float32)
    n_cl = len(M.clustered_names(cfg))
    cbs = jax.ShapeDtypeStruct((n_cl, K.CODEBOOK_PAD), jnp.float32)
    flat = [
        jax.ShapeDtypeStruct(s.shape, jnp.uint8 if s.clustered else jnp.float32)
        for s in M.param_manifest(cfg)
    ]
    return to_hlo_text(jax.jit(fn).lower(img, cbs, *flat))


def lower_micro_modules(cfg: M.ModelConfig, batch: int) -> dict[str, dict]:
    """Per-op-category micro modules at model shapes, for the Fig. 2
    measured execution-time breakdown."""
    t, d, mlp = cfg.n_tokens, cfg.dim, cfg.dim * cfg.mlp_ratio
    rows = batch * t
    f32 = jnp.float32

    def spec(*shape):
        return jax.ShapeDtypeStruct(shape, f32)

    mods = {
        "matmul_qkv": (
            lambda x, w: (ref.matmul(x, w),),
            [spec(rows, d), spec(d, 3 * d)],
        ),
        "matmul_mlp": (
            lambda x, w: (ref.matmul(x, w),),
            [spec(rows, d), spec(d, mlp)],
        ),
        "softmax": (
            lambda s: (ref.softmax(s, axis=-1),),
            [spec(batch * cfg.heads, t, t)],
        ),
        "layernorm": (
            lambda x, g, b: (ref.layernorm(x, g, b),),
            [spec(rows, d), spec(d), spec(d)],
        ),
        "gelu": (lambda x: (ref.gelu(x),), [spec(rows, mlp)]),
    }
    out = {}
    for name, (fn, args) in mods.items():
        out[name] = {
            "hlo": to_hlo_text(jax.jit(fn).lower(*args)),
            "shapes": [list(a.shape) for a in args],
        }
    return out


def accuracy_sweep(
    params: dict[str, np.ndarray],
    cfg: M.ModelConfig,
    val_x: np.ndarray,
    val_y: np.ndarray,
    log=print,
) -> dict:
    """Python-side Figs. 7/8 cross-check: accuracy for every (scheme, c)."""
    out: dict = {"baseline": {}}
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    top1, top5, _ = T.eval_model(jp, cfg, val_x, val_y)
    out["baseline"] = {"top1": top1, "top5": top5}
    for scheme in K.SCHEMES:
        for c in K.CLUSTER_SWEEP:
            cm = K.cluster_params(params, cfg, c, scheme)
            deq = {
                k: jnp.asarray(v)
                for k, v in K.dequantize_params(params, cm, cfg).items()
            }
            t1, t5, _ = T.eval_model(deq, cfg, val_x, val_y)
            out[f"{scheme}_{c}"] = {
                "top1": t1,
                "top5": t5,
                "mse": K.quantization_error(params, cm, cfg),
            }
            log(f"[sweep:{cfg.name}] {scheme} c={c}: top1={t1:.4f} top5={t5:.4f}")
    return out


def run(out_dir: str, quick: bool = False, log=print) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()
    cfgs = model_configs()
    if quick:
        cfgs = {
            name: M.ModelConfig(
                name=name, dim=64, depth=2, heads=2, distilled=(name == "deit")
            )
            for name in cfgs
        }
    steps = _env_int("CLUSTERFORMER_STEPS", 60 if quick else 1000)
    n_train = _env_int("CLUSTERFORMER_NTRAIN", 1024 if quick else 8192)
    n_val = _env_int("CLUSTERFORMER_NVAL", 128 if quick else 512)

    (train_x, train_y), (val_x, val_y) = T.make_splits(n_train, n_val)
    tnsr.write_tpak(
        os.path.join(out_dir, "val.tpak"), {"images": val_x, "labels": val_y}
    )

    manifest: dict = {
        "version": 1,
        "quick": quick,
        "data": {
            "val": "val.tpak",
            "n_val": int(n_val),
            "n_classes": int(val_y.max()) + 1 if len(val_y) else 10,
            "img_size": int(val_x.shape[1]),
            "class_names": __import__(
                "compile.data", fromlist=["CLASS_NAMES"]
            ).CLASS_NAMES,
        },
        "cluster_sweep": list(K.CLUSTER_SWEEP),
        "schemes": list(K.SCHEMES),
        "codebook_pad": K.CODEBOOK_PAD,
        "batch_sizes": list(BATCH_SIZES),
        "golden_n": GOLDEN_N,
        "models": {},
        "micro_hlo": {},
    }

    teacher_logits = None
    accuracy_all: dict = {}
    for name, cfg in cfgs.items():
        log(f"=== {name}: train ({steps} steps) ===")
        params, curve = T.train_model(
            cfg,
            train_x,
            train_y,
            steps=steps,
            teacher_logits=teacher_logits if cfg.distilled else None,
            log=log,
        )
        pn = {k: np.asarray(v) for k, v in params.items()}
        top1, top5, val_logits = T.eval_model(params, cfg, val_x, val_y)
        log(f"[{name}] baseline top1={top1:.4f} top5={top5:.4f}")
        if name == "vit":
            # teacher for DeiT distillation: ViT logits on the train set
            fwd = jax.jit(lambda p, x: M.forward(p, x, cfg))
            outs = [
                np.asarray(fwd(params, jnp.asarray(train_x[i : i + 64])))
                for i in range(0, n_train, 64)
            ]
            teacher_logits = np.concatenate(outs, axis=0)

        tnsr.write_tpak(os.path.join(out_dir, f"{name}_weights.tpak"), pn)

        entry: dict = {
            "config": cfg.to_dict(),
            "params": [
                {"name": s.name, "shape": list(s.shape), "clustered": s.clustered}
                for s in M.param_manifest(cfg)
            ],
            "weights": f"{name}_weights.tpak",
            "clustered": {},
            "hlo": {"baseline": {}, "clustered": {}},
            "loss_curve": curve,
            "baseline_top1": top1,
            "baseline_top5": top5,
        }

        # ---- clustered variants ----
        for scheme in K.SCHEMES:
            for c in K.CLUSTER_SWEEP:
                cm = K.cluster_params(pn, cfg, c, scheme)
                fname = f"{name}_clustered_{scheme}_{c}.tpak"
                pack = {f"idx/{k}": v for k, v in cm.indices.items()}
                pack["codebooks"] = cm.codebooks
                tnsr.write_tpak(os.path.join(out_dir, fname), pack)
                entry["clustered"][f"{scheme}_{c}"] = {
                    "file": fname,
                    "table_bytes": cm.table_of_centroids_bytes(),
                }
        log(f"[{name}] clustered variants written")

        # ---- HLO lowering ----
        for b in BATCH_SIZES:
            fb = f"{name}_{b}_baseline.hlo.txt"
            fc = f"{name}_{b}_clustered.hlo.txt"
            with open(os.path.join(out_dir, fb), "w") as f:
                f.write(lower_baseline(cfg, b))
            with open(os.path.join(out_dir, fc), "w") as f:
                f.write(lower_clustered(cfg, b))
            entry["hlo"]["baseline"][str(b)] = fb
            entry["hlo"]["clustered"][str(b)] = fc
            log(f"[{name}] lowered HLO batch={b}")

        # ---- goldens (ref-path logits for Rust integration tests) ----
        gx = val_x[:GOLDEN_N]
        goldens = {
            "images": gx,
            "labels": val_y[:GOLDEN_N],
            "baseline_logits": val_logits[:GOLDEN_N],
        }
        cm64 = K.cluster_params(pn, cfg, 64, "perlayer")
        deq = {
            k: jnp.asarray(v) for k, v in K.dequantize_params(pn, cm64, cfg).items()
        }
        _, _, cl_logits = T.eval_model(deq, cfg, gx, val_y[:GOLDEN_N])
        goldens["clustered_perlayer_64_logits"] = cl_logits
        tnsr.write_tpak(os.path.join(out_dir, f"{name}_goldens.tpak"), goldens)
        entry["goldens"] = f"{name}_goldens.tpak"

        # ---- python-side accuracy sweep (Figs. 7/8 cross-check) ----
        accuracy_all[name] = accuracy_sweep(pn, cfg, val_x, val_y, log=log)
        manifest["models"][name] = entry

    # ---- micro modules for the Fig. 2 breakdown (model-shape ops) ----
    any_cfg = cfgs["vit"]
    micro = lower_micro_modules(any_cfg, batch=8)
    for op, m in micro.items():
        fname = f"micro_{op}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(m["hlo"])
        manifest["micro_hlo"][op] = {"file": fname, "shapes": m["shapes"]}

    with open(os.path.join(out_dir, "accuracy_python.json"), "w") as f:
        json.dump(accuracy_all, f, indent=1)
    manifest["accuracy_python"] = "accuracy_python.json"
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"artifacts complete in {time.time() - t_start:.0f}s -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="tiny models + few steps (CI / pytest fixture)",
    )
    args = ap.parse_args()
    run(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
