"""L2: ViT / DeiT in JAX, with a kernel-backed path for AOT lowering.

Two forward implementations share one parameter layout:

  * ``forward(..., use_kernels=False)`` — pure jnp (fast on CPU); used for
    training and as the oracle for goldens.
  * ``forward(..., use_kernels=True)`` — every matmul / layernorm /
    attention goes through the L1 Pallas kernels; this is the graph that
    ``aot.py`` lowers to HLO for the Rust runtime.

The **parameter manifest** (``param_manifest``) is the contract with the
Rust side: a stable, ordered list of (name, shape, clustered?) that defines
the flat argument order of every AOT-lowered entry point and the layout of
the ``.tpak`` weight files.

DeiT here is the paper's DeiT: identical encoder plus a distillation token
and a second classification head trained against a teacher (train.py); at
inference the two head outputs are averaged (Touvron et al., 2020).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref

# Parameters with at least this many elements get clustered; the paper
# clusters the (large) matmul parameters — biases/LN vectors are left FP32
# and are accounted as such by the Rust memory model.
CLUSTER_MIN_ELEMS = 4096


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "vit"
    img_size: int = 32
    patch: int = 8
    dim: int = 192
    depth: int = 6
    heads: int = 3
    mlp_ratio: int = 4
    n_classes: int = 10
    distilled: bool = False  # True -> DeiT (distillation token + 2nd head)

    @property
    def n_patches(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def n_tokens(self) -> int:
        return self.n_patches + 1 + (1 if self.distilled else 0)

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


VIT_TINY = ModelConfig(name="vit", distilled=False)
DEIT_TINY = ModelConfig(name="deit", distilled=True)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    clustered: bool


def param_manifest(cfg: ModelConfig) -> list[ParamSpec]:
    """Stable ordered parameter list — the AOT/Rust interface contract."""
    d, mlp = cfg.dim, cfg.dim * cfg.mlp_ratio
    specs: list[ParamSpec] = []

    def add(name: str, *shape: int, force_fp: bool = False):
        n_elems = int(np.prod(shape))
        clustered = (not force_fp) and n_elems >= CLUSTER_MIN_ELEMS
        specs.append(ParamSpec(name, tuple(shape), clustered))

    add("patch_embed/w", cfg.patch_dim, d)
    add("patch_embed/b", d)
    # Embedding-type parameters stay FP32 (they are read once per image,
    # not per matmul, and are small).
    add("pos_embed", cfg.n_tokens, d, force_fp=True)
    add("cls_token", d)
    if cfg.distilled:
        add("dist_token", d)
    for i in range(cfg.depth):
        p = f"blocks/{i}"
        add(f"{p}/ln1/g", d)
        add(f"{p}/ln1/b", d)
        add(f"{p}/qkv/w", d, 3 * d)
        add(f"{p}/qkv/b", 3 * d)
        add(f"{p}/proj/w", d, d)
        add(f"{p}/proj/b", d)
        add(f"{p}/ln2/g", d)
        add(f"{p}/ln2/b", d)
        add(f"{p}/fc1/w", d, mlp)
        add(f"{p}/fc1/b", mlp)
        add(f"{p}/fc2/w", mlp, d)
        add(f"{p}/fc2/b", d)
    add("ln_f/g", d)
    add("ln_f/b", d)
    add("head/w", d, cfg.n_classes, force_fp=cfg.n_classes * d < CLUSTER_MIN_ELEMS)
    add("head/b", cfg.n_classes)
    if cfg.distilled:
        add(
            "head_dist/w",
            d,
            cfg.n_classes,
            force_fp=cfg.n_classes * d < CLUSTER_MIN_ELEMS,
        )
        add("head_dist/b", cfg.n_classes)
    return specs


def clustered_names(cfg: ModelConfig) -> list[str]:
    return [s.name for s in param_manifest(cfg) if s.clustered]


def init_params(cfg: ModelConfig, seed: int) -> dict[str, jnp.ndarray]:
    """Truncated-normal(0.02) weights, zero biases, unit LN gains."""
    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    for spec in param_manifest(cfg):
        last = spec.name.rsplit("/", 1)[-1]
        if last in ("b",):
            v = np.zeros(spec.shape, dtype=np.float32)
        elif last == "g":
            v = np.ones(spec.shape, dtype=np.float32)
        elif spec.name in ("cls_token", "dist_token", "pos_embed"):
            v = (rng.standard_normal(spec.shape) * 0.02).astype(np.float32)
        else:
            v = np.clip(
                rng.standard_normal(spec.shape) * 0.02, -0.04, 0.04
            ).astype(np.float32)
        params[spec.name] = jnp.asarray(v)
    return params


def patchify(images: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[B, H, W, 3] -> [B, n_patches, patch*patch*3]."""
    b = images.shape[0]
    p, g = cfg.patch, cfg.img_size // cfg.patch
    x = images.reshape(b, g, p, g, p, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, p * p * 3)


# ---------------------------------------------------------------------------
# Forward pass (shared skeleton, pluggable primitive ops)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Ops:
    """Primitive op set: either pure-jnp reference or Pallas kernels."""

    matmul: Callable  # (x2d, name) -> y2d  (weight lookup internal)
    layernorm: Callable  # (x2d, g, b) -> y2d
    attention: Callable  # (q, k, v) [B, h, T, hd] -> same


def _ref_ops(params: dict[str, jnp.ndarray]) -> _Ops:
    return _Ops(
        matmul=lambda x, name: ref.matmul(x, params[name]),
        layernorm=ref.layernorm,
        attention=jax.vmap(jax.vmap(ref.attention)),
    )


def _kernel_ops(params: dict[str, jnp.ndarray]) -> _Ops:
    return _Ops(
        matmul=lambda x, name: kernels.matmul(x, params[name]),
        layernorm=kernels.layernorm,
        attention=kernels.attention_batched,
    )


def _clustered_ops(
    params: dict[str, jnp.ndarray],
    codebooks: jnp.ndarray,
    cb_index: dict[str, int],
) -> _Ops:
    """Clustered inference ops: matmul weights are u8 indices + codebook."""

    def matmul(x, name):
        if name in cb_index:
            return kernels.clustered_matmul(
                x, params[name], codebooks[cb_index[name]]
            )
        return kernels.matmul(x, params[name])

    return _Ops(
        matmul=matmul,
        layernorm=kernels.layernorm,
        attention=kernels.attention_batched,
    )


def _encoder(
    x: jnp.ndarray, params: dict[str, jnp.ndarray], cfg: ModelConfig, ops: _Ops
) -> jnp.ndarray:
    """Transformer encoder over token embeddings x [B, T, D]."""
    b, t, d = x.shape

    def mm(x2d, name):
        return ops.matmul(x2d, name)

    for i in range(cfg.depth):
        p = f"blocks/{i}"
        # --- MHSA ---
        h = ops.layernorm(
            x.reshape(b * t, d), params[f"{p}/ln1/g"], params[f"{p}/ln1/b"]
        )
        qkv = mm(h, f"{p}/qkv/w") + params[f"{p}/qkv/b"]
        qkv = qkv.reshape(b, t, 3, cfg.heads, cfg.head_dim)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # [B, h, T, hd]
        k = qkv[:, :, 1].transpose(0, 2, 1, 3)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3)
        a = ops.attention(q, k, v)  # [B, h, T, hd]
        a = a.transpose(0, 2, 1, 3).reshape(b * t, d)
        x = x + (mm(a, f"{p}/proj/w") + params[f"{p}/proj/b"]).reshape(b, t, d)
        # --- MLP ---
        h = ops.layernorm(
            x.reshape(b * t, d), params[f"{p}/ln2/g"], params[f"{p}/ln2/b"]
        )
        h = ref.gelu(mm(h, f"{p}/fc1/w") + params[f"{p}/fc1/b"])
        x = x + (mm(h, f"{p}/fc2/w") + params[f"{p}/fc2/b"]).reshape(b, t, d)
    return x


def _forward_with_ops(
    params: dict[str, jnp.ndarray],
    images: jnp.ndarray,
    cfg: ModelConfig,
    ops: _Ops,
    train_heads: bool = False,
):
    b = images.shape[0]
    patches = patchify(images, cfg)  # [B, P, pd]
    x = ops.matmul(patches.reshape(b * cfg.n_patches, cfg.patch_dim), "patch_embed/w")
    x = (x + params["patch_embed/b"]).reshape(b, cfg.n_patches, cfg.dim)
    toks = [jnp.broadcast_to(params["cls_token"], (b, 1, cfg.dim))]
    if cfg.distilled:
        toks.append(jnp.broadcast_to(params["dist_token"], (b, 1, cfg.dim)))
    x = jnp.concatenate(toks + [x], axis=1) + params["pos_embed"][None]
    x = _encoder(x, params, cfg, ops)
    x = ops.layernorm(
        x.reshape(b * cfg.n_tokens, cfg.dim), params["ln_f/g"], params["ln_f/b"]
    ).reshape(b, cfg.n_tokens, cfg.dim)
    logits_cls = ops.matmul(x[:, 0], "head/w") + params["head/b"]
    if not cfg.distilled:
        return logits_cls
    logits_dist = ops.matmul(x[:, 1], "head_dist/w") + params["head_dist/b"]
    if train_heads:
        return logits_cls, logits_dist
    return (logits_cls + logits_dist) / 2.0


def forward(
    params: dict[str, jnp.ndarray],
    images: jnp.ndarray,
    cfg: ModelConfig,
    use_kernels: bool = False,
    train_heads: bool = False,
):
    """Baseline (FP32-weight) forward pass -> logits [B, n_classes]."""
    ops = _kernel_ops(params) if use_kernels else _ref_ops(params)
    return _forward_with_ops(params, images, cfg, ops, train_heads)


def forward_clustered(
    params: dict[str, jnp.ndarray],
    codebooks: jnp.ndarray,
    images: jnp.ndarray,
    cfg: ModelConfig,
):
    """Clustered forward: `params[name]` for clustered entries holds the u8
    index tensor; `codebooks` is [n_clustered, 256] f32 (padded), row order
    = `clustered_names(cfg)`. One lowered module serves every
    (scheme x cluster-count): smaller tables simply occupy a prefix of the
    256 rows, exactly like the paper's always-8-bit indices (§III-B)."""
    cb_index = {n: i for i, n in enumerate(clustered_names(cfg))}
    ops = _clustered_ops(params, codebooks, cb_index)
    return _forward_with_ops(params, images, cfg, ops)


# ---------------------------------------------------------------------------
# Flat entry points for AOT lowering (argument order = manifest order)
# ---------------------------------------------------------------------------


def params_to_flat(
    params: dict[str, jnp.ndarray], cfg: ModelConfig
) -> list[jnp.ndarray]:
    return [params[s.name] for s in param_manifest(cfg)]


def flat_to_params(
    flat: list[jnp.ndarray], cfg: ModelConfig
) -> dict[str, jnp.ndarray]:
    specs = param_manifest(cfg)
    assert len(flat) == len(specs)
    return {s.name: a for s, a in zip(specs, flat)}


def make_baseline_fn(cfg: ModelConfig, use_kernels: bool = True):
    def fn(images, *flat):
        params = flat_to_params(list(flat), cfg)
        return (forward(params, images, cfg, use_kernels=use_kernels),)

    return fn


def make_clustered_fn(cfg: ModelConfig):
    def fn(images, codebooks, *flat):
        params = flat_to_params(list(flat), cfg)
        return (forward_clustered(params, codebooks, images, cfg),)

    return fn
