"""Synthetic procedurally-generated shape-classification dataset.

Substitution for the ImageNet-1k validation set the paper uses
(DESIGN.md §Substitutions): the accuracy-vs-cluster-count *shape* depends
on the quantization error of the weight distribution, not on the dataset,
so a learnable 10-class dataset with controlled difficulty is sufficient
to reproduce Figs. 7/8.

All generation is seeded and pure-numpy: `make artifacts` is bit-for-bit
reproducible.
"""

from __future__ import annotations

import numpy as np

IMG_SIZE = 32
N_CLASSES = 10

CLASS_NAMES = [
    "circle",
    "square",
    "triangle",
    "cross",
    "ring",
    "hstripes",
    "vstripes",
    "checker",
    "diagonal",
    "dots",
]


def _grid(size: int):
    y, x = np.mgrid[0:size, 0:size].astype(np.float32)
    return x, y


def _draw(cls: int, rng: np.random.Generator, size: int) -> np.ndarray:
    """Render one [size, size] mask for a class with randomized geometry."""
    x, y = _grid(size)
    cx = rng.uniform(size * 0.35, size * 0.65)
    cy = rng.uniform(size * 0.35, size * 0.65)
    r = rng.uniform(size * 0.18, size * 0.32)
    if cls == 0:  # circle
        return ((x - cx) ** 2 + (y - cy) ** 2 <= r**2).astype(np.float32)
    if cls == 1:  # square
        return (
            (np.abs(x - cx) <= r) & (np.abs(y - cy) <= r)
        ).astype(np.float32)
    if cls == 2:  # triangle (upward)
        h = r * 1.6
        return (
            (y >= cy - h / 2)
            & (y <= cy + h / 2)
            & (np.abs(x - cx) <= (y - (cy - h / 2)) / 2)
        ).astype(np.float32)
    if cls == 3:  # cross
        w = r * 0.45
        return (
            ((np.abs(x - cx) <= w) & (np.abs(y - cy) <= r))
            | ((np.abs(y - cy) <= w) & (np.abs(x - cx) <= r))
        ).astype(np.float32)
    if cls == 4:  # ring
        d2 = (x - cx) ** 2 + (y - cy) ** 2
        return ((d2 <= r**2) & (d2 >= (r * 0.55) ** 2)).astype(np.float32)
    if cls == 5:  # horizontal stripes
        period = rng.uniform(4.0, 8.0)
        phase = rng.uniform(0, period)
        return (((y + phase) % period) < period / 2).astype(np.float32)
    if cls == 6:  # vertical stripes
        period = rng.uniform(4.0, 8.0)
        phase = rng.uniform(0, period)
        return (((x + phase) % period) < period / 2).astype(np.float32)
    if cls == 7:  # checkerboard
        period = rng.uniform(5.0, 9.0)
        return (
            (((x // (period / 2)) + (y // (period / 2))) % 2) == 0
        ).astype(np.float32)
    if cls == 8:  # diagonal stripes
        period = rng.uniform(5.0, 10.0)
        phase = rng.uniform(0, period)
        return (((x + y + phase) % period) < period / 2).astype(np.float32)
    if cls == 9:  # dot grid
        period = rng.uniform(6.0, 10.0)
        rr = period * 0.28
        return (
            ((x % period - period / 2) ** 2 + (y % period - period / 2) ** 2)
            <= rr**2
        ).astype(np.float32)
    raise ValueError(f"unknown class {cls}")


def make_dataset(
    n: int, seed: int, size: int = IMG_SIZE, noise: float = 0.15
) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` images [n, size, size, 3] f32 in [0,1] + labels [n] i32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    images = np.empty((n, size, size, 3), dtype=np.float32)
    for i in range(n):
        mask = _draw(int(labels[i]), rng, size)
        fg = rng.uniform(0.55, 1.0, size=3).astype(np.float32)
        bg = rng.uniform(0.0, 0.35, size=3).astype(np.float32)
        img = mask[:, :, None] * fg[None, None, :] + (1 - mask[:, :, None]) * (
            bg[None, None, :]
        )
        img += rng.normal(0, noise, size=img.shape).astype(np.float32)
        images[i] = np.clip(img, 0.0, 1.0)
    return images, labels
