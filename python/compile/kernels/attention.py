"""Pallas fused scaled-dot-product-attention kernel (flash-style).

Single head: q,k,v [T, D] -> [T, D]. The grid tiles the query axis; for
each query tile the kernel streams KV tiles through an online-softmax
accumulator (running max `m`, running denominator `l`, weighted-value
accumulator `acc`), so the full [T, T] score matrix never materializes in
VMEM — the same trick FlashAttention uses for CUDA shared memory, mapped
here onto the Pallas BlockSpec/VMEM model.

Batch/head axes are handled with jax.vmap at the call site (model.py),
which in Pallas becomes leading grid dimensions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bkv: int, n_kv: int):
    q = q_ref[...]  # [bq, D]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    q = q * scale

    def body(i, carry):
        m_prev, l_prev, acc = carry
        kt = pl.load(k_ref, (pl.dslice(i * bkv, bkv), slice(None)))  # [bkv, D]
        vt = pl.load(v_ref, (pl.dslice(i * bkv, bkv), slice(None)))
        s = jnp.dot(q, kt.T, preferred_element_type=jnp.float32)  # [bq, bkv]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, vt, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    bq, d = q.shape
    init = (
        jnp.full((bq,), _NEG_INF, dtype=jnp.float32),
        jnp.zeros((bq,), dtype=jnp.float32),
        jnp.zeros((bq, d), dtype=jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, n_kv, body, init)
    o_ref[...] = acc / l[:, None]


def _largest_divisor(n: int, cap: int) -> int:
    cap = min(n, cap)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "interpret"))
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Fused softmax(q·kᵀ/√d)·v for one head; see module docstring."""
    t, d = q.shape
    assert k.shape == (t, d) and v.shape == (t, d)
    bq = _largest_divisor(t, bq)
    bkv = _largest_divisor(t, bkv)
    n_kv = t // bkv
    return pl.pallas_call(
        functools.partial(_attn_kernel, bkv=bkv, n_kv=n_kv),
        grid=(t // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            # K and V are not blocked: the kernel slices its own KV tiles so
            # the online-softmax loop controls the stream order.
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((t, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def attention_batched(q, k, v, **kw):
    """vmap over leading (batch, head) axes: [..., T, D] -> [..., T, D]."""
    fn = functools.partial(attention, **kw)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
