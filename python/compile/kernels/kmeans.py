"""Pallas kernel for the K-means assignment step on scalar parameters.

The compression pipeline (compile/kmeans.py) clusters millions of scalar
weights against <=256 centroids; the assignment step is the O(N*C) hot
loop. The kernel tiles the point stream through VMEM while the centroid
table (like the inference-side table of centroids) stays pinned across the
whole grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _assign_kernel(p_ref, c_ref, o_ref):
    p = p_ref[...]  # [bp]
    c = c_ref[...]  # [C]
    d = jnp.abs(p[:, None] - c[None, :])  # [bp, C]
    o_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)


def _largest_divisor(n: int, cap: int) -> int:
    cap = min(n, cap)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def kmeans_assign(
    points: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    bp: int = 4096,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Nearest-centroid index (int32) for each scalar point."""
    (n,) = points.shape
    (c,) = centroids.shape
    bp = _largest_divisor(n, bp)
    return pl.pallas_call(
        _assign_kernel,
        grid=(n // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(points, centroids)
