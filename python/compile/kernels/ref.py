"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the single source of correctness truth: `python/tests/` sweeps the
Pallas kernels (interpret mode) against these with hypothesis-generated
shapes and asserts allclose, and `aot.py` exports goldens computed through
the L2 model built on these references so the Rust integration tests can
check end-to-end numerics.
"""

from __future__ import annotations

import jax.numpy as jnp


def dequantize(indices: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct an FP32 weight tensor from u8 indices + table of centroids.

    indices : uint8, any shape.
    codebook: float32 [C] (C <= 256; padded tables simply carry unused rows).
    """
    return codebook[indices.astype(jnp.int32)]


def clustered_matmul(
    x: jnp.ndarray, indices: jnp.ndarray, codebook: jnp.ndarray
) -> jnp.ndarray:
    """x @ dequantize(indices, codebook).

    x      : float32 [M, K]
    indices: uint8   [K, N]
    codebook: float32 [C]
    """
    w = dequantize(indices, codebook)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def clustered_matmul_bias_gelu(
    x: jnp.ndarray,
    indices: jnp.ndarray,
    codebook: jnp.ndarray,
    bias: jnp.ndarray,
    apply_gelu: bool = True,
) -> jnp.ndarray:
    """Fused clustered matmul + bias (+ tanh-approx GELU)."""
    y = clustered_matmul(x, indices, codebook) + bias
    return gelu(y) if apply_gelu else y


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the kernel's polynomial)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def softmax(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def layernorm(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    """Row-wise layer norm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Single-head scaled dot-product attention.

    q, k, v: float32 [T, D] -> [T, D]
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    return jnp.dot(softmax(scores, axis=-1), v, preferred_element_type=jnp.float32)


def kmeans_assign(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Assignment step of Lloyd's algorithm on scalar weights.

    points   : float32 [N]   (flattened parameters)
    centroids: float32 [C]
    returns  : int32  [N]  index of the nearest centroid.
    """
    d = jnp.abs(points[:, None] - centroids[None, :])
    return jnp.argmin(d, axis=1).astype(jnp.int32)
