"""Pallas kernel for matmul over K-means-clustered weights.

This is the paper's "specific kernel to operate on clustered data"
(§V-B): instead of streaming FP32 weights from HBM, the kernel streams
**uint8 centroid indices** (4x less traffic) and keeps the small table of
centroids resident in VMEM for the whole grid, performing the indirect
fetch (Fig. 5 of the paper) on-chip.

TPU adaptation of the paper's CUDA design (DESIGN.md §Hardware-Adaptation):

  * the CUDA version parks the table of centroids in shared memory; here
    the codebook BlockSpec maps every grid step to the same (0,) block so
    it stays pinned in VMEM (256 x f32 = 1 KiB);
  * the weight tile dequantization is a VPU gather (or, see
    `one_hot=True`, an MXU-friendly one-hot matmul), after which the
    dequantized tile feeds the MXU exactly like the baseline kernel;
  * BlockSpec expresses the HBM->VMEM schedule the CUDA kernel expressed
    with threadblock tiling: grid = (M/bm, N/bn, K/bk) with the K axis
    innermost so the f32 accumulator tile stays in place.

All pallas_calls use interpret=True: the CPU PJRT client cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO (correctness path);
real-TPU efficiency is estimated structurally in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (block sizes must tile exactly)."""
    cap = min(n, cap)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def _dequant_tile(idx_tile, cb, one_hot: bool):
    """Dequantize a u8 index tile against the in-VMEM codebook."""
    ii = idx_tile.astype(jnp.int32)
    if one_hot:
        # MXU-friendly variant: [bk*bn, C] one-hot @ [C] -> gather as matmul.
        oh = jax.nn.one_hot(ii, cb.shape[0], dtype=cb.dtype)
        return jnp.einsum("knc,c->kn", oh, cb)
    return cb[ii]


def _kernel(x_ref, idx_ref, cb_ref, o_ref, *, n_k_blocks: int, one_hot: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_tile = _dequant_tile(idx_ref[...], cb_ref[...], one_hot)
    o_ref[...] += jnp.dot(
        x_ref[...], w_tile, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "one_hot", "interpret")
)
def clustered_matmul(
    x: jnp.ndarray,
    indices: jnp.ndarray,
    codebook: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    one_hot: bool = False,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """x[M,K] @ dequantize(indices[K,N] u8, codebook[C] f32) -> [M,N] f32."""
    m, k = x.shape
    k2, n = indices.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _largest_divisor(m, bm)
    bn = _largest_divisor(n, bn)
    bk = _largest_divisor(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_k_blocks=grid[2], one_hot=one_hot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            # Codebook: same (whole) block at every grid step -> pinned in
            # VMEM, fetched from HBM once. This is the paper's table of
            # centroids living in on-chip memory.
            pl.BlockSpec((codebook.shape[0],), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, indices, codebook)


def _fused_kernel(
    x_ref, idx_ref, cb_ref, b_ref, o_ref, *, n_k_blocks: int,
    one_hot: bool, apply_gelu: bool
):
    # The output tile has the same (i, j) index map at every k step, so it
    # stays resident in VMEM and doubles as the accumulator; the epilogue
    # rewrites it in place on the final k step.
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_tile = _dequant_tile(idx_ref[...], cb_ref[...], one_hot)
    o_ref[...] += jnp.dot(
        x_ref[...], w_tile, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k_blocks - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...][None, :]
        if apply_gelu:
            c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
            y = 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
        o_ref[...] = y


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "one_hot", "apply_gelu", "interpret"),
)
def clustered_matmul_bias_gelu(
    x: jnp.ndarray,
    indices: jnp.ndarray,
    codebook: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    one_hot: bool = False,
    apply_gelu: bool = True,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Fused clustered matmul + bias (+ GELU) — the MLP-block hot path.

    Fusing the epilogue into the kernel keeps the [bm, bn] output tile in
    VMEM across accumulate -> bias -> GELU instead of a round trip to HBM
    between three HLO ops.
    """
    m, k = x.shape
    k2, n = indices.shape
    assert k == k2 and bias.shape == (n,)
    bm = _largest_divisor(m, bm)
    bn = _largest_divisor(n, bn)
    bk = _largest_divisor(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(
            _fused_kernel,
            n_k_blocks=grid[2],
            one_hot=one_hot,
            apply_gelu=apply_gelu,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((codebook.shape[0],), lambda i, j, kk: (0,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, indices, codebook, bias)


def _plain_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Baseline FP32 tiled matmul — identical schedule to clustered_matmul,
    but streaming 4x the weight bytes. The comparison kernel for Fig. 9."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm = _largest_divisor(m, bm)
    bn = _largest_divisor(n, bn)
    bk = _largest_divisor(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _plain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
