"""Pallas fused layer-norm kernel.

Rows are tiled across the grid; each kernel invocation normalizes a
[br, D] tile in one VMEM round trip (mean, variance, scale, shift fused),
where the unfused HLO graph would make four passes over the row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * g_ref[...][None, :] + b_ref[
        ...
    ][None, :]


def _largest_divisor(n: int, cap: int) -> int:
    cap = min(n, cap)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit, static_argnames=("br", "eps", "interpret"))
def layernorm(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    br: int = 128,
    eps: float = 1e-6,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """Layer norm over the last axis of x [R, D]."""
    r, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    br = _largest_divisor(r, br)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(x, gamma, beta)
