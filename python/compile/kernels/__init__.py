"""L1: Pallas kernels for the paper's compute hot spots.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are checked against the pure-jnp oracles in ``ref.py``.
"""

from .attention import attention, attention_batched  # noqa: F401
from .clustered_matmul import (  # noqa: F401
    clustered_matmul,
    clustered_matmul_bias_gelu,
    matmul,
)
from .kmeans import kmeans_assign  # noqa: F401
from .layernorm import layernorm  # noqa: F401
