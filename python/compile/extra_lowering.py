"""Supplementary lowerings for the §Perf L2 study (no retraining).

Loads the already-trained weights from `artifacts/` and lowers additional
module variants used by the performance pass:

  * `{model}_{b}_refpath.hlo.txt` — the pure-jnp forward (no Pallas
    interpret loops): XLA is free to fuse, which on the CPU PJRT backend
    is the relevant roofline for the L2 graph. Comparing its wall time
    against the kernel-path module isolates the cost of interpret-mode
    Pallas (grid while-loops) from the model itself.
  * `{model}_{b}_clustered_refpath.hlo.txt` — same, clustered: dequantize
    (gather) + matmul as plain jnp ops.

Run: cd python && python -m compile.extra_lowering [--out ../artifacts]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import kmeans as K
from . import model as M
from . import tnsr
from .aot import to_hlo_text
from .kernels import ref


def make_refpath_fn(cfg: M.ModelConfig):
    def fn(images, *flat):
        params = M.flat_to_params(list(flat), cfg)
        return (M.forward(params, images, cfg, use_kernels=False),)

    return fn


def make_clustered_refpath_fn(cfg: M.ModelConfig):
    """Clustered forward with plain-jnp dequantize + matmul (no Pallas)."""
    cb_index = {n: i for i, n in enumerate(M.clustered_names(cfg))}

    def fn(images, codebooks, *flat):
        params = dict(M.flat_to_params(list(flat), cfg))
        for name, row in cb_index.items():
            params[name] = ref.dequantize(params[name], codebooks[row])
        return (M.forward(params, images, cfg, use_kernels=False),)

    return fn


def lower(cfg: M.ModelConfig, batch: int, clustered: bool) -> str:
    img = jax.ShapeDtypeStruct((batch, cfg.img_size, cfg.img_size, 3), jnp.float32)
    if clustered:
        n_cl = len(M.clustered_names(cfg))
        cbs = jax.ShapeDtypeStruct((n_cl, K.CODEBOOK_PAD), jnp.float32)
        flat = [
            jax.ShapeDtypeStruct(s.shape, jnp.uint8 if s.clustered else jnp.float32)
            for s in M.param_manifest(cfg)
        ]
        return to_hlo_text(jax.jit(make_clustered_refpath_fn(cfg)).lower(img, cbs, *flat))
    flat = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in M.param_manifest(cfg)]
    return to_hlo_text(jax.jit(make_refpath_fn(cfg)).lower(img, *flat))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", default="8")
    args = ap.parse_args()
    import json

    manifest = json.load(open(os.path.join(args.out, "manifest.json")))
    for name, entry in manifest["models"].items():
        cfg = M.ModelConfig(**entry["config"])
        # sanity: weights exist and match the manifest
        weights = tnsr.read_tpak(os.path.join(args.out, entry["weights"]))
        assert set(weights) == {p["name"] for p in entry["params"]}
        for b in [int(x) for x in args.batches.split(",")]:
            for clustered, tag in [(False, "refpath"), (True, "clustered_refpath")]:
                path = os.path.join(args.out, f"{name}_{b}_{tag}.hlo.txt")
                with open(path, "w") as f:
                    f.write(lower(cfg, b, clustered))
                print(f"wrote {path}")
    # correctness spot-check: refpath logits == ref forward on 2 images
    entry = manifest["models"]["vit"]
    cfg = M.ModelConfig(**entry["config"])
    weights = tnsr.read_tpak(os.path.join(args.out, entry["weights"]))
    params = {k: jnp.asarray(v) for k, v in weights.items()}
    val = tnsr.read_tpak(os.path.join(args.out, "val.tpak"))
    imgs = jnp.asarray(val["images"][:2])
    want = M.forward(params, imgs, cfg)
    fn = make_refpath_fn(cfg)
    got = fn(imgs, *M.params_to_flat(params, cfg))[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    print("refpath spot-check OK")


if __name__ == "__main__":
    main()
