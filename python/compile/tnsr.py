"""`.tnsr` / `.tpak` binary tensor interchange format (Python writer/reader).

The Rust side (`rust/src/tensor/io.rs`) implements the same format; this is
the only data channel between the build-time Python layer and the runtime
Rust layer besides HLO text.

tpak layout (little-endian):

    magic   b"TPAK"
    u32     version (1)
    u32     n_entries
    entries:
        u16     name_len, name bytes (utf-8)
        u8      dtype (0=f32, 1=u8, 2=i32, 3=i64)
        u8      ndim
        u64*ndim dims
        u64     payload bytes
        payload
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TPAK"
VERSION = 1

_DTYPES = {0: np.float32, 1: np.uint8, 2: np.int32, 3: np.int64}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def write_tpak(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            # ascontiguousarray promotes 0-d to 1-d; restore the shape
            arr = np.ascontiguousarray(arr).reshape(arr.shape)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}Q", *arr.shape))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def read_tpak(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = f.read(nbytes)
            arr = np.frombuffer(data, dtype=_DTYPES[code]).reshape(dims)
            out[name] = arr
    return out
