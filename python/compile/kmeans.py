"""K-means clustering of model parameters (the paper's §III-B).

Scalar (1-D) K-means over parameter values, two scopes:

  * ``entire``   — one codebook shared by every clustered tensor
                   (paper Fig. 6a);
  * ``perlayer`` — one codebook per clustered tensor (paper Fig. 6b).

Implementation notes
--------------------
For 1-D points Lloyd's algorithm is done exactly and fast with a
sort/`digitize` sweep: the nearest-centroid regions of sorted centroids
are the half-open intervals between midpoints, so assignment is a binary
search (O(N log C) per iteration) instead of an O(N*C) distance matrix.
The Pallas ``kmeans_assign`` kernel computes the same assignment and is
cross-checked against this in python/tests; the Rust `clustering` module
re-implements this pipeline and is cross-validated against the `.tpak`
artifacts this module writes.

Initialization is deterministic (quantiles of the empirical distribution),
which for 1-D data both avoids empty clusters and makes `make artifacts`
reproducible without seed plumbing.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .model import ModelConfig, clustered_names

CODEBOOK_PAD = 256  # paper §III-B: always 8-bit indices, even for c < 256

SCHEMES = ("entire", "perlayer")
CLUSTER_SWEEP = (8, 16, 32, 64, 128, 256)


def lloyd_1d(
    points: np.ndarray, n_clusters: int, iters: int = 40, tol: float = 1e-7
) -> np.ndarray:
    """Exact 1-D Lloyd iterations from quantile init; returns sorted centroids."""
    pts = np.asarray(points, dtype=np.float64).ravel()
    if pts.size == 0:
        raise ValueError("cannot cluster zero points")
    n_clusters = min(n_clusters, np.unique(pts).size)
    # Quantile init: equal-mass intervals of the empirical distribution.
    qs = (np.arange(n_clusters) + 0.5) / n_clusters
    centroids = np.quantile(pts, qs)
    order = np.argsort(pts, kind="stable")
    sorted_pts = pts[order]
    csum = np.concatenate([[0.0], np.cumsum(sorted_pts)])
    for _ in range(iters):
        centroids = np.unique(centroids)  # collapse duplicates
        bounds = (centroids[1:] + centroids[:-1]) / 2.0
        # Index of first sorted point in each centroid's region.
        starts = np.concatenate(
            [[0], np.searchsorted(sorted_pts, bounds), [pts.size]]
        )
        counts = np.diff(starts)
        sums = csum[starts[1:]] - csum[starts[:-1]]
        new = np.where(counts > 0, sums / np.maximum(counts, 1), centroids)
        shift = np.max(np.abs(new - centroids)) if new.size == centroids.size else np.inf
        centroids = new
        if shift < tol:
            break
    return np.sort(centroids).astype(np.float64)


def assign_1d(points: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Nearest-centroid (ties -> lower index) via midpoint binary search."""
    c = np.sort(np.asarray(centroids, dtype=np.float64))
    bounds = (c[1:] + c[:-1]) / 2.0
    return np.searchsorted(bounds, np.asarray(points, dtype=np.float64)).astype(
        np.int64
    )


def inertia(points: np.ndarray, centroids: np.ndarray) -> float:
    idx = assign_1d(points, centroids)
    c = np.sort(np.asarray(centroids, dtype=np.float64))
    return float(np.sum((points.astype(np.float64) - c[idx]) ** 2))


@dataclasses.dataclass
class ClusteredModel:
    """Clustered representation of one model's parameters."""

    scheme: str  # "entire" | "perlayer"
    n_clusters: int
    # u8 index tensor per clustered parameter (same shape as the original).
    indices: dict[str, np.ndarray]
    # [n_clustered_tensors, 256] f32, row order = clustered_names(cfg);
    # for "entire" every row is the same table.
    codebooks: np.ndarray

    def table_of_centroids_bytes(self) -> int:
        """Real (unpadded) storage of the table(s) of centroids, paper §V-C."""
        n_tables = 1 if self.scheme == "entire" else self.codebooks.shape[0]
        return n_tables * self.n_clusters * 4


def _pad_codebook(centroids: np.ndarray) -> np.ndarray:
    cb = np.zeros(CODEBOOK_PAD, dtype=np.float32)
    cb[: centroids.size] = centroids.astype(np.float32)
    return cb


def cluster_params(
    params: dict[str, np.ndarray],
    cfg: ModelConfig,
    n_clusters: int,
    scheme: str,
    iters: int = 40,
) -> ClusteredModel:
    """Cluster the model's matmul parameters into `n_clusters` centroids."""
    if scheme not in SCHEMES:
        raise ValueError(f"scheme must be one of {SCHEMES}, got {scheme!r}")
    if not 2 <= n_clusters <= CODEBOOK_PAD:
        raise ValueError(f"n_clusters must be in [2, {CODEBOOK_PAD}]")
    names = clustered_names(cfg)
    indices: dict[str, np.ndarray] = {}
    rows: list[np.ndarray] = []
    if scheme == "entire":
        allpts = np.concatenate(
            [np.asarray(params[n], dtype=np.float32).ravel() for n in names]
        )
        centroids = lloyd_1d(allpts, n_clusters, iters)
        cb = _pad_codebook(centroids)
        for n in names:
            w = np.asarray(params[n], dtype=np.float32)
            indices[n] = assign_1d(w.ravel(), centroids).astype(np.uint8).reshape(w.shape)
            rows.append(cb)
    else:
        for n in names:
            w = np.asarray(params[n], dtype=np.float32)
            centroids = lloyd_1d(w.ravel(), n_clusters, iters)
            indices[n] = assign_1d(w.ravel(), centroids).astype(np.uint8).reshape(w.shape)
            rows.append(_pad_codebook(centroids))
    return ClusteredModel(
        scheme=scheme,
        n_clusters=n_clusters,
        indices=indices,
        codebooks=np.stack(rows, axis=0),
    )


def dequantize_params(
    params: dict[str, np.ndarray], cm: ClusteredModel, cfg: ModelConfig
) -> dict[str, np.ndarray]:
    """Reconstruct an FP32 parameter dict from a clustered model (oracle for
    the clustered forward pass and source of the Rust goldens)."""
    out = dict(params)
    for i, n in enumerate(clustered_names(cfg)):
        out[n] = cm.codebooks[i][cm.indices[n].astype(np.int32)]
    return out


def quantization_error(
    params: dict[str, np.ndarray], cm: ClusteredModel, cfg: ModelConfig
) -> float:
    """Mean squared reconstruction error over all clustered parameters."""
    deq = dequantize_params(params, cm, cfg)
    num, den = 0.0, 0
    for n in clustered_names(cfg):
        d = np.asarray(params[n], dtype=np.float64) - deq[n].astype(np.float64)
        num += float(np.sum(d * d))
        den += d.size
    return num / max(den, 1)
