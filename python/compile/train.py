"""Build-time training of the ViT and DeiT models on the synthetic dataset.

Runs once inside `make artifacts`; never on the request path. The optimizer
is a from-scratch Adam with cosine decay (optax is deliberately not a
dependency). DeiT uses hard-label distillation from the trained ViT
teacher, as in Touvron et al. (2020): the class-token head learns the
ground truth while the distillation-token head learns the teacher's
argmax.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as M


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy_topk(logits: np.ndarray, labels: np.ndarray, k: int) -> float:
    topk = np.argsort(-logits, axis=1)[:, :k]
    return float(np.mean(np.any(topk == labels[:, None], axis=1)))


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.05):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat_scale = 1.0 / (1 - b1**t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2**t.astype(jnp.float32))
    new_params = {}
    for k in params:
        upd = (m[k] * mhat_scale) / (jnp.sqrt(v[k] * vhat_scale) + eps)
        # decoupled weight decay on matmul weights only
        if k.endswith("/w"):
            upd = upd + wd * params[k]
        new_params[k] = params[k] - lr * upd
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, base=1e-3, warmup=50, floor=1e-5):
    warm = base * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (base - floor) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def train_model(
    cfg: M.ModelConfig,
    train_images: np.ndarray,
    train_labels: np.ndarray,
    steps: int,
    batch: int = 64,
    seed: int = 0,
    teacher_logits: np.ndarray | None = None,
    log_every: int = 100,
    log=print,
) -> tuple[dict[str, jnp.ndarray], list[tuple[int, float]]]:
    """Train one model; returns (params, loss curve [(step, loss)])."""
    params = M.init_params(cfg, seed)
    state = adam_init(params)
    distilled = cfg.distilled and teacher_logits is not None
    teacher_labels = (
        np.argmax(teacher_logits, axis=1).astype(np.int32) if distilled else None
    )

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, state, images, labels, tlabels, lr):
        def loss_fn(p):
            if distilled:
                lc, ld = M.forward(p, images, cfg, train_heads=True)
                return 0.5 * cross_entropy(lc, labels) + 0.5 * cross_entropy(
                    ld, tlabels
                )
            logits = M.forward(p, images, cfg)
            return cross_entropy(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    rng = np.random.default_rng(seed + 1)
    n = train_images.shape[0]
    curve: list[tuple[int, float]] = []
    t0 = time.time()
    for step in range(steps):
        sel = rng.integers(0, n, size=batch)
        lr = cosine_lr(step, steps)
        tl = (
            jnp.asarray(teacher_labels[sel])
            if distilled
            else jnp.zeros(batch, jnp.int32)
        )
        params, state, loss = step_fn(
            params,
            state,
            jnp.asarray(train_images[sel]),
            jnp.asarray(train_labels[sel]),
            tl,
            lr,
        )
        if step % log_every == 0 or step == steps - 1:
            lv = float(loss)
            curve.append((step, lv))
            log(
                f"[train:{cfg.name}] step {step:5d}/{steps} "
                f"loss {lv:.4f} ({time.time() - t0:.0f}s)"
            )
    return params, curve


def eval_model(
    params: dict[str, jnp.ndarray],
    cfg: M.ModelConfig,
    images: np.ndarray,
    labels: np.ndarray,
    batch: int = 64,
) -> tuple[float, float, np.ndarray]:
    """Returns (top1, top5, logits) on the given split (pure-jnp path)."""
    fwd = jax.jit(lambda p, x: M.forward(p, x, cfg))
    outs = []
    for i in range(0, images.shape[0], batch):
        outs.append(np.asarray(fwd(params, jnp.asarray(images[i : i + batch]))))
    logits = np.concatenate(outs, axis=0)
    return (
        accuracy_topk(logits, labels, 1),
        accuracy_topk(logits, labels, 5),
        logits,
    )


def make_splits(n_train: int, n_val: int, seed: int = 1234):
    train_x, train_y = data_mod.make_dataset(n_train, seed=seed)
    val_x, val_y = data_mod.make_dataset(n_val, seed=seed + 999)
    return (train_x, train_y), (val_x, val_y)
