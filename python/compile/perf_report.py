"""L1 structural performance report (EXPERIMENTS.md §Perf).

interpret=True wallclock is CPU-numpy time, not a TPU proxy, so the
Pallas kernels are optimized *structurally*: this script computes, for
each kernel at the model's shapes, the per-grid-step VMEM footprint, the
HBM traffic per output tile, the arithmetic intensity delta vs the FP32
baseline, and an MXU-utilization estimate from tile alignment.

Run: cd python && python -m compile.perf_report
"""

from __future__ import annotations

import dataclasses

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPUs
MXU = 128  # systolic array edge


@dataclasses.dataclass
class MatmulSpec:
    name: str
    m: int
    k: int
    n: int
    bm: int = 128
    bn: int = 128
    bk: int = 128

    def tiles(self):
        ceil = lambda a, b: -(-a // b)
        return ceil(self.m, self.bm), ceil(self.n, self.bn), ceil(self.k, self.bk)


def report_clustered_matmul(spec: MatmulSpec, codebook_rows: int = 256):
    """Per-grid-step accounting for the clustered vs baseline kernel."""
    bm, bn, bk = min(spec.bm, spec.m), min(spec.bn, spec.n), min(spec.bk, spec.k)
    # VMEM residency per step
    x_tile = bm * bk * 4
    idx_tile = bk * bn * 1          # u8 index stream
    w_tile_fp32 = bk * bn * 4       # baseline weight stream
    cb = codebook_rows * 4          # pinned for the whole grid
    deq_tile = bk * bn * 4          # dequantized tile (VPU output)
    out_tile = bm * bn * 4
    vmem_clustered = x_tile + idx_tile + cb + deq_tile + out_tile
    vmem_baseline = x_tile + w_tile_fp32 + out_tile
    # HBM traffic for the whole matmul (weight stream only; x reused)
    mt, nt, kt = spec.tiles()
    weight_traffic_base = spec.k * spec.n * 4 * mt  # re-fetched per m-tile
    weight_traffic_clus = spec.k * spec.n * 1 * mt + cb
    # MXU utilization estimate: fraction of the 128x128 array covered
    util = (min(bm, MXU) / MXU) * (min(bn, MXU) / MXU)
    flops = 2 * spec.m * spec.k * spec.n
    return {
        "name": spec.name,
        "grid": (mt, nt, kt),
        "vmem_clustered_B": vmem_clustered,
        "vmem_baseline_B": vmem_baseline,
        "vmem_fits": vmem_clustered * 2 <= VMEM_BYTES,  # x2 for double-buffer
        "weight_traffic_reduction": weight_traffic_base / weight_traffic_clus,
        "intensity_base": flops / (weight_traffic_base + spec.m * spec.k * 4),
        "intensity_clus": flops / (weight_traffic_clus + spec.m * spec.k * 4),
        "mxu_utilization": util,
    }


def model_matmuls(batch: int, t: int = 17, d: int = 192, mlp: int = 768):
    rows = batch * t
    return [
        MatmulSpec("patch_embed", batch * 16, 192, d),
        MatmulSpec("qkv", rows, d, 3 * d),
        MatmulSpec("proj", rows, d, d),
        MatmulSpec("fc1", rows, d, mlp),
        MatmulSpec("fc2", rows, mlp, d),
    ]


def main() -> None:
    print(f"{'kernel':<14} {'grid':>10} {'VMEM clus':>10} {'fits':>5} "
          f"{'Wtraffic x':>10} {'AI base':>8} {'AI clus':>8} {'MXU':>6}")
    for batch in (1, 8):
        print(f"-- batch {batch} --")
        for spec in model_matmuls(batch):
            r = report_clustered_matmul(spec)
            print(
                f"{r['name']:<14} {str(r['grid']):>10} "
                f"{r['vmem_clustered_B']:>10,} {str(r['vmem_fits']):>5} "
                f"{r['weight_traffic_reduction']:>9.2f}x "
                f"{r['intensity_base']:>8.2f} {r['intensity_clus']:>8.2f} "
                f"{r['mxu_utilization']:>5.1%}"
            )
    print(
        "\nNotes: codebook (1 KiB) pinned in VMEM across the grid; index"
        "\nstream is u8 so HBM weight traffic drops ~4x; M-dim tiles are"
        "\nbatch*17 tokens, so MXU row coverage grows with batch (the"
        "\nedge-serving batcher's job). Double-buffered footprint stays"
        "\norders of magnitude under the 16 MiB VMEM budget."
    )


if __name__ == "__main__":
    main()
