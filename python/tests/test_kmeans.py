"""Compression pipeline invariants: 1-D Lloyd, assignment, schemes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kmeans as K
from compile import model as M

SETTINGS = dict(max_examples=30, deadline=None)
CFG = M.ModelConfig(name="vit", dim=64, depth=2, heads=2)


@st.composite
def points(draw):
    n = draw(st.integers(4, 2000))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 10.0))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


class TestLloyd1D:
    @given(points(), st.sampled_from([2, 4, 16, 64]))
    @settings(**SETTINGS)
    def test_centroids_sorted_within_range(self, pts, c):
        cents = K.lloyd_1d(pts, c)
        assert np.all(np.diff(cents) >= 0)
        assert cents.min() >= pts.min() - 1e-9
        assert cents.max() <= pts.max() + 1e-9

    @given(points(), st.sampled_from([2, 8, 32]))
    @settings(**SETTINGS)
    def test_lloyd_improves_on_init(self, pts, c):
        qs = (np.arange(min(c, np.unique(pts).size)) + 0.5) / c
        init = np.quantile(pts.astype(np.float64), qs)
        assert K.inertia(pts, K.lloyd_1d(pts, c)) <= K.inertia(pts, init) + 1e-6

    @given(points())
    @settings(**SETTINGS)
    def test_more_clusters_not_worse(self, pts):
        i8 = K.inertia(pts, K.lloyd_1d(pts, 8))
        i64 = K.inertia(pts, K.lloyd_1d(pts, 64))
        assert i64 <= i8 + 1e-6

    def test_exact_when_clusters_cover_uniques(self):
        pts = np.asarray([1.0, 1.0, 5.0, 5.0, 9.0], dtype=np.float32)
        cents = K.lloyd_1d(pts, 3)
        assert K.inertia(pts, cents) < 1e-12

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            K.lloyd_1d(np.zeros(0, dtype=np.float32), 4)


class TestAssign1D:
    @given(points(), st.sampled_from([2, 8, 64]))
    @settings(**SETTINGS)
    def test_assignment_is_nearest(self, pts, c):
        cents = K.lloyd_1d(pts, c)
        idx = K.assign_1d(pts, cents)
        d = np.abs(pts[:, None].astype(np.float64) - cents[None, :])
        best = d.min(axis=1)
        chosen = d[np.arange(len(pts)), idx]
        np.testing.assert_allclose(chosen, best, atol=1e-12)

    def test_index_range(self):
        pts = np.linspace(-1, 1, 100).astype(np.float32)
        cents = K.lloyd_1d(pts, 16)
        idx = K.assign_1d(pts, cents)
        assert idx.min() >= 0 and idx.max() < len(cents)


class TestClusterParams:
    def _params(self, seed=0):
        return {k: np.asarray(v) for k, v in M.init_params(CFG, seed).items()}

    @pytest.mark.parametrize("scheme", K.SCHEMES)
    def test_shapes_and_dtypes(self, scheme):
        pn = self._params()
        cm = K.cluster_params(pn, CFG, 16, scheme)
        names = M.clustered_names(CFG)
        assert set(cm.indices) == set(names)
        assert cm.codebooks.shape == (len(names), K.CODEBOOK_PAD)
        assert cm.codebooks.dtype == np.float32
        for n in names:
            assert cm.indices[n].dtype == np.uint8
            assert cm.indices[n].shape == pn[n].shape
            assert cm.indices[n].max() < 16

    def test_entire_shares_one_table(self):
        cm = K.cluster_params(self._params(), CFG, 32, "entire")
        for row in cm.codebooks[1:]:
            np.testing.assert_array_equal(row, cm.codebooks[0])

    def test_perlayer_tables_differ(self):
        cm = K.cluster_params(self._params(), CFG, 32, "perlayer")
        assert not all(
            np.array_equal(cm.codebooks[0], r) for r in cm.codebooks[1:]
        )

    def test_error_decreases_with_clusters(self):
        pn = self._params()
        errs = [
            K.quantization_error(pn, K.cluster_params(pn, CFG, c, "perlayer"), CFG)
            for c in (8, 32, 128)
        ]
        assert errs[0] > errs[1] > errs[2]

    def test_perlayer_competitive_with_entire(self):
        # Per-layer is not a strict theorem per-value (quantile init can
        # favour the pooled distribution at high c), but it must be
        # competitive everywhere and clearly better in the low-c regime
        # (the paper's Fig. 7 point).
        pn = self._params()
        for c in (8, 64):
            e_ent = K.quantization_error(pn, K.cluster_params(pn, CFG, c, "entire"), CFG)
            e_pl = K.quantization_error(pn, K.cluster_params(pn, CFG, c, "perlayer"), CFG)
            assert e_pl <= e_ent * 1.10, f"c={c}: {e_pl} vs {e_ent}"

    def test_table_bytes(self):
        pn = self._params()
        cm_e = K.cluster_params(pn, CFG, 64, "entire")
        assert cm_e.table_of_centroids_bytes() == 64 * 4  # paper §V-C: 256 B
        cm_p = K.cluster_params(pn, CFG, 64, "perlayer")
        assert cm_p.table_of_centroids_bytes() == len(M.clustered_names(CFG)) * 64 * 4

    def test_invalid_args(self):
        pn = self._params()
        with pytest.raises(ValueError):
            K.cluster_params(pn, CFG, 64, "bogus")
        with pytest.raises(ValueError):
            K.cluster_params(pn, CFG, 1, "entire")
        with pytest.raises(ValueError):
            K.cluster_params(pn, CFG, 512, "entire")

    def test_dequantize_reconstruction(self):
        pn = self._params()
        cm = K.cluster_params(pn, CFG, 256, "perlayer")
        deq = K.dequantize_params(pn, cm, CFG)
        for n in M.clustered_names(CFG):
            err = np.max(np.abs(deq[n] - pn[n]))
            assert err < 0.01, f"{n}: {err}"
        # non-clustered params pass through untouched
        for n in ("pos_embed", "cls_token"):
            np.testing.assert_array_equal(deq[n], pn[n])
