"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes and index distributions; every
property asserts allclose against `kernels.ref`.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 24))
    k = draw(st.integers(1, 24))
    n = draw(st.integers(1, 24))
    bm = draw(st.integers(1, 16))
    bn = draw(st.integers(1, 16))
    bk = draw(st.integers(1, 16))
    c = draw(st.sampled_from([2, 8, 64, 256]))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, bm, bn, bk, c, seed


class TestClusteredMatmul:
    @given(matmul_case())
    @settings(**SETTINGS)
    def test_matches_ref(self, case):
        m, k, n, bm, bn, bk, c, seed = case
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        idx = jnp.asarray(rng.integers(0, c, size=(k, n)), dtype=jnp.uint8)
        cb = rand(rng, 256)
        got = kernels.clustered_matmul(x, idx, cb, bm=bm, bn=bn, bk=bk)
        want = ref.clustered_matmul(x, idx, cb)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @given(matmul_case())
    @settings(max_examples=10, deadline=None)
    def test_one_hot_variant(self, case):
        m, k, n, bm, bn, bk, c, seed = case
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        idx = jnp.asarray(rng.integers(0, c, size=(k, n)), dtype=jnp.uint8)
        cb = rand(rng, 256)
        got = kernels.clustered_matmul(
            x, idx, cb, bm=bm, bn=bn, bk=bk, one_hot=True
        )
        want = ref.clustered_matmul(x, idx, cb)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @given(matmul_case(), st.booleans())
    @settings(**SETTINGS)
    def test_fused_bias_gelu(self, case, apply_gelu):
        m, k, n, bm, bn, bk, c, seed = case
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        idx = jnp.asarray(rng.integers(0, c, size=(k, n)), dtype=jnp.uint8)
        cb = rand(rng, 256)
        b = rand(rng, n)
        got = kernels.clustered_matmul_bias_gelu(
            x, idx, cb, b, bm=bm, bn=bn, bk=bk, apply_gelu=apply_gelu
        )
        want = ref.clustered_matmul_bias_gelu(x, idx, cb, b, apply_gelu)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_uses_only_referenced_centroids(self):
        """Padded codebook rows beyond max(idx) must not affect the result."""
        rng = np.random.default_rng(0)
        x = rand(rng, 4, 8)
        idx = jnp.asarray(rng.integers(0, 16, size=(8, 6)), dtype=jnp.uint8)
        cb1 = np.asarray(rand(rng, 256))
        cb2 = cb1.copy()
        cb2[16:] = 1e6  # poison the unused tail
        y1 = kernels.clustered_matmul(x, idx, jnp.asarray(cb1))
        y2 = kernels.clustered_matmul(x, idx, jnp.asarray(cb2))
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    @given(matmul_case())
    @settings(max_examples=10, deadline=None)
    def test_plain_matmul(self, case):
        m, k, n, bm, bn, bk, _, seed = case
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        w = rand(rng, k, n)
        got = kernels.matmul(x, w, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

    def test_shape_mismatch_raises(self):
        rng = np.random.default_rng(0)
        x = rand(rng, 4, 8)
        idx = jnp.zeros((9, 6), dtype=jnp.uint8)
        with pytest.raises(AssertionError):
            kernels.clustered_matmul(x, idx, rand(rng, 256))


class TestAttention:
    @given(
        st.integers(1, 20),
        st.integers(1, 16),
        st.integers(1, 8),
        st.integers(1, 8),
        st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, t, d, bq, bkv, seed):
        rng = np.random.default_rng(seed)
        q, k, v = (rand(rng, t, d) for _ in range(3))
        got = kernels.attention(q, k, v, bq=bq, bkv=bkv)
        np.testing.assert_allclose(
            got, ref.attention(q, k, v), rtol=1e-4, atol=1e-4
        )

    def test_batched_heads(self):
        rng = np.random.default_rng(7)
        q = rand(rng, 2, 3, 8, 16)
        k = rand(rng, 2, 3, 8, 16)
        v = rand(rng, 2, 3, 8, 16)
        got = kernels.attention_batched(q, k, v, bq=4, bkv=4)
        want = np.stack(
            [
                np.stack(
                    [
                        np.asarray(ref.attention(q[b, h], k[b, h], v[b, h]))
                        for h in range(3)
                    ]
                )
                for b in range(2)
            ]
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    def test_softmax_rows_sum_to_one_effect(self):
        """attention(q,k,const_v) == const_v for any q,k (softmax rows sum=1)."""
        rng = np.random.default_rng(3)
        q, k = rand(rng, 6, 4), rand(rng, 6, 4)
        v = jnp.ones((6, 4), jnp.float32) * 3.25
        got = kernels.attention(q, k, v, bq=2, bkv=3)
        np.testing.assert_allclose(np.asarray(got), 3.25, rtol=1e-5)


class TestLayerNorm:
    @given(
        st.integers(1, 32),
        st.integers(2, 48),
        st.integers(1, 8),
        st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, r, d, br, seed):
        rng = np.random.default_rng(seed)
        x, g, b = rand(rng, r, d), rand(rng, d), rand(rng, d)
        got = kernels.layernorm(x, g, b, br=br)
        np.testing.assert_allclose(
            got, ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4
        )

    def test_normalizes_rows(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 8, 64) * 10 + 3
        y = kernels.layernorm(
            x, jnp.ones(64, jnp.float32), jnp.zeros(64, jnp.float32)
        )
        np.testing.assert_allclose(np.mean(np.asarray(y), axis=1), 0, atol=1e-4)
        np.testing.assert_allclose(np.std(np.asarray(y), axis=1), 1, atol=1e-3)


class TestKmeansAssign:
    @given(
        st.integers(1, 512),
        st.integers(1, 64),
        st.integers(1, 128),
        st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, n, c, bp, seed):
        rng = np.random.default_rng(seed)
        p = rand(rng, n)
        cents = rand(rng, c)
        got = kernels.kmeans_assign(p, cents, bp=bp)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.kmeans_assign(p, cents))
        )

    def test_assignment_is_nearest(self):
        rng = np.random.default_rng(11)
        p = rand(rng, 300)
        cents = rand(rng, 17)
        idx = np.asarray(kernels.kmeans_assign(p, cents))
        d = np.abs(np.asarray(p)[:, None] - np.asarray(cents)[None, :])
        chosen = d[np.arange(300), idx]
        assert np.all(chosen <= d.min(axis=1) + 1e-6)
