"""Dataset determinism/coverage and training-loop machinery."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import model as M
from compile import train as T


class TestData:
    def test_deterministic(self):
        a, la = D.make_dataset(32, seed=7)
        b, lb = D.make_dataset(32, seed=7)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_seed_changes_data(self):
        a, _ = D.make_dataset(16, seed=1)
        b, _ = D.make_dataset(16, seed=2)
        assert not np.array_equal(a, b)

    def test_shapes_range(self):
        x, y = D.make_dataset(64, seed=3)
        assert x.shape == (64, D.IMG_SIZE, D.IMG_SIZE, 3)
        assert x.dtype == np.float32
        assert x.min() >= 0.0 and x.max() <= 1.0
        assert y.min() >= 0 and y.max() < D.N_CLASSES

    def test_all_classes_reachable(self):
        _, y = D.make_dataset(500, seed=4)
        assert set(np.unique(y)) == set(range(D.N_CLASSES))

    @given(st.integers(0, 9), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_draw_masks_binary(self, cls, seed):
        rng = np.random.default_rng(seed)
        m = D._draw(cls, rng, D.IMG_SIZE)
        assert m.shape == (D.IMG_SIZE, D.IMG_SIZE)
        assert set(np.unique(m)).issubset({0.0, 1.0})


class TestTrainMachinery:
    def test_cross_entropy_perfect_prediction(self):
        logits = jnp.asarray([[100.0, 0, 0], [0, 100.0, 0]])
        labels = jnp.asarray([0, 1])
        assert float(T.cross_entropy(logits, labels)) < 1e-3

    def test_accuracy_topk(self):
        logits = np.asarray(
            [[0.9, 0.1, 0.0], [0.2, 0.3, 0.5], [0.6, 0.3, 0.1]], dtype=np.float32
        )
        labels = np.asarray([0, 1, 2])
        assert T.accuracy_topk(logits, labels, 1) == 1 / 3
        assert T.accuracy_topk(logits, labels, 2) == 2 / 3
        assert T.accuracy_topk(logits, labels, 3) == 1.0

    def test_cosine_lr_schedule(self):
        total = 200
        lrs = [float(T.cosine_lr(s, total)) for s in (0, 49, 50, 125, 199)]
        assert lrs[0] < lrs[1]  # warmup rises
        assert lrs[2] >= lrs[3] >= lrs[4]  # cosine decays
        assert lrs[4] >= 1e-5

    def test_adam_moves_toward_gradient(self):
        params = {"x/w": jnp.asarray([1.0, -1.0])}
        grads = {"x/w": jnp.asarray([1.0, -1.0])}
        state = T.adam_init(params)
        new, state = T.adam_update(params, grads, state, lr=0.1, wd=0.0)
        assert float(new["x/w"][0]) < 1.0
        assert float(new["x/w"][1]) > -1.0

    def test_short_training_reduces_loss(self):
        cfg = M.ModelConfig(name="vit", dim=32, depth=1, heads=2)
        (tx, ty), _ = T.make_splits(256, 32)
        _, curve = T.train_model(
            cfg, tx, ty, steps=60, batch=32, log_every=59, log=lambda *a: None
        )
        assert curve[-1][1] < curve[0][1]

    def test_distillation_path_runs(self):
        cfg = M.ModelConfig(name="deit", dim=32, depth=1, heads=2, distilled=True)
        (tx, ty), _ = T.make_splits(128, 16)
        teacher = np.random.default_rng(0).standard_normal(
            (128, 10)
        ).astype(np.float32)
        params, curve = T.train_model(
            cfg,
            tx,
            ty,
            steps=5,
            batch=16,
            teacher_logits=teacher,
            log_every=4,
            log=lambda *a: None,
        )
        assert len(curve) >= 1
        assert "dist_token" in params
