"""`.tpak` interchange format: roundtrips and error handling."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tnsr


@st.composite
def tensor(draw):
    dtype = draw(st.sampled_from([np.float32, np.uint8, np.int32, np.int64]))
    ndim = draw(st.integers(0, 4))
    shape = tuple(draw(st.integers(1, 6)) for _ in range(ndim))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dtype == np.float32:
        return rng.standard_normal(shape).astype(dtype)
    return rng.integers(0, 100, size=shape).astype(dtype)


@given(st.dictionaries(st.text(min_size=1, max_size=40), tensor(), max_size=6))
@settings(max_examples=30, deadline=None)
def test_roundtrip(tmp_path_factory, tensors):
    path = str(tmp_path_factory.mktemp("tpak") / "x.tpak")
    tnsr.write_tpak(path, tensors)
    back = tnsr.read_tpak(path)
    assert set(back) == set(tensors)
    for k, v in tensors.items():
        assert back[k].dtype == v.dtype
        assert back[k].shape == v.shape
        np.testing.assert_array_equal(back[k], v)


def test_empty_pack(tmp_path):
    path = str(tmp_path / "e.tpak")
    tnsr.write_tpak(path, {})
    assert tnsr.read_tpak(path) == {}

def test_scalar_tensor(tmp_path):
    path = str(tmp_path / "s.tpak")
    tnsr.write_tpak(path, {"s": np.float32(3.5).reshape(())})
    back = tnsr.read_tpak(path)
    assert back["s"].shape == ()
    assert back["s"] == np.float32(3.5)


def test_bad_magic(tmp_path):
    path = str(tmp_path / "bad.tpak")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError, match="bad magic"):
        tnsr.read_tpak(path)


def test_unsupported_dtype(tmp_path):
    path = str(tmp_path / "f.tpak")
    with pytest.raises(TypeError):
        tnsr.write_tpak(path, {"x": np.zeros(3, dtype=np.float64)})


def test_non_contiguous_input(tmp_path):
    path = str(tmp_path / "nc.tpak")
    arr = np.arange(24, dtype=np.float32).reshape(4, 6)[:, ::2]
    tnsr.write_tpak(path, {"x": arr})
    np.testing.assert_array_equal(tnsr.read_tpak(path)["x"], arr)
