"""L2 correctness: model forward passes, manifest contract, clustered path."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import kmeans as K
from compile import model as M

CFG_V = M.ModelConfig(name="vit", dim=64, depth=2, heads=2)
CFG_D = M.ModelConfig(name="deit", dim=64, depth=2, heads=2, distilled=True)


def _imgs(b, cfg=CFG_V, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.uniform(0, 1, (b, cfg.img_size, cfg.img_size, 3)).astype(np.float32)
    )


class TestConfig:
    def test_token_counts(self):
        assert CFG_V.n_patches == 16
        assert CFG_V.n_tokens == 17
        assert CFG_D.n_tokens == 18

    def test_head_dim_divides(self):
        with pytest.raises(AssertionError):
            _ = M.ModelConfig(dim=65, heads=2).head_dim


class TestManifest:
    def test_is_stable_and_ordered(self):
        a = M.param_manifest(CFG_V)
        b = M.param_manifest(CFG_V)
        assert a == b
        names = [s.name for s in a]
        assert len(names) == len(set(names)), "duplicate parameter names"

    def test_deit_has_distillation_params(self):
        names = {s.name for s in M.param_manifest(CFG_D)}
        assert "dist_token" in names and "head_dist/w" in names
        vit_names = {s.name for s in M.param_manifest(CFG_V)}
        assert "dist_token" not in vit_names

    def test_clustered_selection(self):
        for spec in M.param_manifest(CFG_V):
            n_elems = int(np.prod(spec.shape))
            if spec.clustered:
                assert n_elems >= M.CLUSTER_MIN_ELEMS
                assert spec.name.endswith("/w")
            if spec.name in ("pos_embed", "cls_token"):
                assert not spec.clustered

    def test_flat_roundtrip(self):
        params = M.init_params(CFG_D, 0)
        flat = M.params_to_flat(params, CFG_D)
        back = M.flat_to_params(flat, CFG_D)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(params[k]))


class TestPatchify:
    def test_shape(self):
        x = M.patchify(_imgs(3), CFG_V)
        assert x.shape == (3, CFG_V.n_patches, CFG_V.patch_dim)

    def test_preserves_pixels(self):
        imgs = _imgs(1)
        patches = M.patchify(imgs, CFG_V)
        # first patch = top-left 8x8 block, row-major
        want = np.asarray(imgs)[0, :8, :8, :].reshape(-1)
        np.testing.assert_array_equal(np.asarray(patches)[0, 0], want)


class TestForward:
    def test_logit_shapes(self):
        for cfg in (CFG_V, CFG_D):
            params = M.init_params(cfg, 0)
            out = M.forward(params, _imgs(4, cfg), cfg)
            assert out.shape == (4, cfg.n_classes)

    def test_kernel_path_matches_ref_path(self):
        for cfg in (CFG_V, CFG_D):
            params = M.init_params(cfg, 1)
            imgs = _imgs(2, cfg, seed=2)
            lr = M.forward(params, imgs, cfg, use_kernels=False)
            lk = M.forward(params, imgs, cfg, use_kernels=True)
            np.testing.assert_allclose(
                np.asarray(lr), np.asarray(lk), rtol=3e-4, atol=3e-4
            )

    def test_deit_train_heads(self):
        params = M.init_params(CFG_D, 0)
        lc, ld = M.forward(params, _imgs(2, CFG_D), CFG_D, train_heads=True)
        assert lc.shape == ld.shape == (2, CFG_D.n_classes)
        avg = M.forward(params, _imgs(2, CFG_D), CFG_D)
        np.testing.assert_allclose(
            np.asarray(avg), (np.asarray(lc) + np.asarray(ld)) / 2, rtol=1e-5
        )

    def test_batch_invariance(self):
        """Same image gives the same logits regardless of batch context."""
        params = M.init_params(CFG_V, 4)
        imgs = _imgs(3, seed=5)
        full = np.asarray(M.forward(params, imgs, CFG_V))
        one = np.asarray(M.forward(params, imgs[1:2], CFG_V))
        np.testing.assert_allclose(full[1:2], one, rtol=1e-4, atol=1e-5)


class TestClusteredForward:
    @pytest.mark.parametrize("scheme", K.SCHEMES)
    @pytest.mark.parametrize("cfg", [CFG_V, CFG_D], ids=["vit", "deit"])
    def test_matches_dequantized_oracle(self, scheme, cfg):
        params = M.init_params(cfg, 3)
        pn = {k: np.asarray(v) for k, v in params.items()}
        cm = K.cluster_params(pn, cfg, 32, scheme)
        cp = {
            k: (jnp.asarray(cm.indices[k]) if k in cm.indices else params[k])
            for k in pn
        }
        imgs = _imgs(2, cfg, seed=9)
        got = M.forward_clustered(cp, jnp.asarray(cm.codebooks), imgs, cfg)
        deq = {
            k: jnp.asarray(v) for k, v in K.dequantize_params(pn, cm, cfg).items()
        }
        want = M.forward(deq, imgs, cfg)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4
        )

    def test_c256_padding_identity(self):
        """With c=256 (no padding), clustered fwd ~= baseline fwd."""
        cfg = CFG_V
        params = M.init_params(cfg, 6)
        pn = {k: np.asarray(v) for k, v in params.items()}
        cm = K.cluster_params(pn, cfg, 256, "perlayer")
        cp = {
            k: (jnp.asarray(cm.indices[k]) if k in cm.indices else params[k])
            for k in pn
        }
        imgs = _imgs(2, seed=10)
        got = np.asarray(M.forward_clustered(cp, jnp.asarray(cm.codebooks), imgs, cfg))
        want = np.asarray(M.forward(params, imgs, cfg))
        # 256 clusters on an init'ed (dense-near-zero) model is a fine grid:
        # logits should be close but not identical.
        np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)
