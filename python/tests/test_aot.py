"""AOT lowering: HLO text generation and module interfaces."""

import numpy as np
import pytest

from compile import aot
from compile import kmeans as K
from compile import model as M

CFG = M.ModelConfig(name="vit", dim=32, depth=1, heads=2)
CFG_D = M.ModelConfig(name="deit", dim=32, depth=1, heads=2, distilled=True)


class TestLowering:
    def test_baseline_hlo_text(self):
        text = aot.lower_baseline(CFG, batch=2)
        assert text.startswith("HloModule")
        assert "f32[2,32,32,3]" in text  # image input shape present

    def test_clustered_hlo_text(self):
        text = aot.lower_clustered(CFG_D, batch=1)
        assert text.startswith("HloModule")
        assert "u8[" in text  # uint8 index inputs present
        n_cl = len(M.clustered_names(CFG_D))
        assert f"f32[{n_cl},{K.CODEBOOK_PAD}]" in text  # codebook stack input

    def test_parameter_count_matches_manifest(self):
        text = aot.lower_baseline(CFG, batch=1)
        # Count entry parameters from the header layout (subcomputations
        # like while bodies carry their own `parameter(0)` instructions).
        layout = text.split("entry_computation_layout={(", 1)[1]
        depth, n_params, i = 0, 1, 0
        while i < len(layout):
            c = layout[i]
            if c in "([{":
                depth += 1
            elif c == ")" and depth == 0:
                break
            elif c in ")]}":
                depth -= 1
            elif c == "," and depth == 0:
                n_params += 1
            i += 1
        assert n_params == 1 + len(M.param_manifest(CFG))  # images + params

    def test_micro_modules(self):
        mods = aot.lower_micro_modules(CFG, batch=2)
        assert set(mods) == {
            "matmul_qkv",
            "matmul_mlp",
            "softmax",
            "layernorm",
            "gelu",
        }
        for name, m in mods.items():
            assert m["hlo"].startswith("HloModule"), name
            assert all(isinstance(s, list) for s in m["shapes"])


class TestConfigPlumbing:
    def test_model_configs_env(self, monkeypatch):
        monkeypatch.setenv("CLUSTERFORMER_DIM", "96")
        monkeypatch.setenv("CLUSTERFORMER_DEPTH", "3")
        cfgs = aot.model_configs()
        assert cfgs["vit"].dim == 96 and cfgs["vit"].depth == 3
        assert cfgs["deit"].distilled and not cfgs["vit"].distilled

    def test_batch_sizes_sane(self):
        assert 1 in aot.BATCH_SIZES and max(aot.BATCH_SIZES) <= 64
